//! Host-side tensors: parameter sets (the model/update/velocity vectors the
//! coordinator moves around) and input batches, with XLA literal conversion.

use anyhow::{bail, Context, Result};

use super::manifest::Manifest;

/// A full set of model parameters (or accumulated updates / velocities),
/// stored leaf-wise in the manifest's sorted-name order. All leaves are f32.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSet {
    pub leaves: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Load from the raw little-endian f32 blob emitted by aot.py.
    pub fn from_bytes(manifest: &Manifest, bytes: &[u8]) -> Result<Self> {
        if bytes.len() != 4 * manifest.total_param_numel {
            bail!(
                "param blob is {} bytes, manifest expects {}",
                bytes.len(),
                4 * manifest.total_param_numel
            );
        }
        let mut leaves = Vec::with_capacity(manifest.params.len());
        let mut off = 0usize;
        for p in &manifest.params {
            let mut leaf = Vec::with_capacity(p.numel);
            for i in 0..p.numel {
                let s = off + 4 * i;
                leaf.push(f32::from_le_bytes([bytes[s], bytes[s + 1], bytes[s + 2], bytes[s + 3]]));
            }
            off += 4 * p.numel;
            leaves.push(leaf);
        }
        Ok(ParamSet { leaves })
    }

    pub fn load(manifest: &Manifest, dir: &std::path::Path) -> Result<Self> {
        let path = manifest.param_file(dir);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(manifest, &bytes)
    }

    /// All-zero set with the same structure (for U accumulators / velocity).
    pub fn zeros_like(&self) -> Self {
        ParamSet { leaves: self.leaves.iter().map(|l| vec![0.0; l.len()]).collect() }
    }

    pub fn num_leaves(&self) -> usize {
        self.leaves.len()
    }

    pub fn total_numel(&self) -> usize {
        self.leaves.iter().map(|l| l.len()).sum()
    }

    pub fn l2_norm(&self) -> f64 {
        self.leaves
            .iter()
            .flat_map(|l| l.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn max_abs_diff(&self, other: &Self) -> f32 {
        self.leaves
            .iter()
            .zip(&other.leaves)
            .flat_map(|(a, b)| a.iter().zip(b.iter()))
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max)
    }

    pub fn zero_(&mut self) {
        for leaf in &mut self.leaves {
            leaf.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    pub fn is_finite(&self) -> bool {
        self.leaves.iter().all(|l| l.iter().all(|x| x.is_finite()))
    }

    /// Serialize to the same raw little-endian f32 format as
    /// `init_params.bin` (checkpointing).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 * self.total_numel());
        for leaf in &self.leaves {
            for v in leaf {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Save to a checkpoint file (atomic-ish: write then rename).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_bytes())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Convert to one XLA literal per leaf (shapes from the manifest).
    pub fn to_literals(&self, manifest: &Manifest) -> Result<Vec<xla::Literal>> {
        debug_assert_eq!(self.leaves.len(), manifest.params.len());
        self.leaves
            .iter()
            .zip(&manifest.params)
            .map(|(leaf, meta)| f32_literal(leaf, &meta.shape))
            .collect()
    }
}

pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)?)
}

pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)?)
}

/// Mini-batch payload: f32 features or i32 tokens/labels.
#[derive(Clone, Debug)]
pub enum BatchData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl BatchData {
    pub fn len(&self) -> usize {
        match self {
            BatchData::F32(v) => v.len(),
            BatchData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A (possibly k-stacked) input batch: `dims` is the full literal shape,
/// e.g. `[K, B, 32, 32, 3]` for the CNN's xs or `[K, B]` for its labels.
#[derive(Clone, Debug)]
pub struct Batch {
    pub dims: Vec<usize>,
    pub data: BatchData,
}

impl Batch {
    pub fn f32(dims: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Batch { dims, data: BatchData::F32(data) }
    }

    pub fn i32(dims: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(dims.iter().product::<usize>(), data.len());
        Batch { dims, data: BatchData::I32(data) }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        match &self.data {
            BatchData::F32(v) => f32_literal(v, &self.dims),
            BatchData::I32(v) => i32_literal(v, &self.dims),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{EvalMeta, ParamMeta, StepVariant};

    fn tiny_manifest() -> Manifest {
        Manifest {
            model: "t".into(),
            seed: 0,
            params: vec![
                ParamMeta { name: "a".into(), shape: vec![2, 2], numel: 4 },
                ParamMeta { name: "b".into(), shape: vec![3], numel: 3 },
            ],
            total_param_numel: 7,
            bytes_per_commit: 28,
            x_shape: vec![1],
            x_dtype: "f32".into(),
            y_shape: vec![],
            y_dtype: "i32".into(),
            num_classes: 2,
            local_steps: vec![StepVariant { k: 1, b: 1, file: "x".into() }],
            eval: EvalMeta { b: 1, file: "x".into() },
            apply: "x".into(),
            apply_momentum: "x".into(),
            init_params: "x".into(),
            init_params_sha256: String::new(),
            jax_version: String::new(),
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let m = tiny_manifest();
        let vals: Vec<f32> = (0..7).map(|i| i as f32 * 0.5 - 1.0).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let ps = ParamSet::from_bytes(&m, &bytes).unwrap();
        assert_eq!(ps.leaves.len(), 2);
        assert_eq!(ps.leaves[0], vals[..4]);
        assert_eq!(ps.leaves[1], vals[4..]);
        assert_eq!(ps.total_numel(), 7);
    }

    #[test]
    fn wrong_length_rejected() {
        let m = tiny_manifest();
        assert!(ParamSet::from_bytes(&m, &[0u8; 12]).is_err());
    }

    #[test]
    fn zeros_and_norms() {
        let m = tiny_manifest();
        let bytes = vec![0u8; 28];
        let mut ps = ParamSet::from_bytes(&m, &bytes).unwrap();
        assert_eq!(ps.l2_norm(), 0.0);
        ps.leaves[0][0] = 3.0;
        ps.leaves[1][2] = 4.0;
        assert!((ps.l2_norm() - 5.0).abs() < 1e-9);
        let z = ps.zeros_like();
        assert_eq!(z.total_numel(), 7);
        assert_eq!(z.l2_norm(), 0.0);
        assert!((ps.max_abs_diff(&z) - 4.0).abs() < 1e-9);
        assert!(ps.is_finite());
    }
}
