//! The artifact contract, mirrored from `python/compile/aot.py` (keep the two
//! in sync — the calling convention is documented in `python/compile/model.py`).
//!
//! Flattened argument order for every artifact follows the *sorted* parameter
//! name order recorded in `params`:
//!
//! * `local_steps_k{K}_b{B}`: params P…, U P…, xs `[K,B,*x_shape]`,
//!   ys `[K,B,*y_shape]`, eta' `f32[]` → params' P…, U' P…, losses `f32[K]`
//! * `eval_step_b{B}`: params P…, x, y → loss `f32[]`, correct `f32[]`
//! * `apply_commit`: W P…, U P…, eta → W' P…
//! * `apply_commit_momentum`: W P…, U P…, V P…, eta, mu → W' P…, V' P…

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
}

#[derive(Clone, Debug)]
pub struct StepVariant {
    /// Number of local steps fused into one execute (lax.scan length).
    pub k: usize,
    /// Mini-batch size.
    pub b: usize,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct EvalMeta {
    pub b: usize,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub seed: u64,
    pub params: Vec<ParamMeta>,
    pub total_param_numel: usize,
    pub bytes_per_commit: usize,
    pub x_shape: Vec<usize>,
    pub x_dtype: String,
    pub y_shape: Vec<usize>,
    pub y_dtype: String,
    pub num_classes: usize,
    pub local_steps: Vec<StepVariant>,
    pub eval: EvalMeta,
    pub apply: String,
    pub apply_momentum: String,
    pub init_params: String,
    pub init_params_sha256: String,
    pub jax_version: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} (run `make artifacts`?)"))?;
        let m = Self::from_json_str(&text)
            .with_context(|| format!("parsing manifest {path:?}"))?;
        m.validate(dir)?;
        Ok(m)
    }

    pub fn from_json_str(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let params = v
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamMeta {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p.req("shape")?.usize_vec()?,
                    numel: p.req("numel")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let local_steps = v
            .req("local_steps")?
            .as_arr()?
            .iter()
            .map(|e| {
                Ok(StepVariant {
                    k: e.req("k")?.as_usize()?,
                    b: e.req("b")?.as_usize()?,
                    file: e.req("file")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let eval = EvalMeta {
            b: v.req("eval")?.req("b")?.as_usize()?,
            file: v.req("eval")?.req("file")?.as_str()?.to_string(),
        };
        Ok(Manifest {
            model: v.req("model")?.as_str()?.to_string(),
            seed: v.u64_or("seed", 0)?,
            params,
            total_param_numel: v.req("total_param_numel")?.as_usize()?,
            bytes_per_commit: v.req("bytes_per_commit")?.as_usize()?,
            x_shape: v.req("x_shape")?.usize_vec()?,
            x_dtype: v.req("x_dtype")?.as_str()?.to_string(),
            y_shape: v.req("y_shape")?.usize_vec()?,
            y_dtype: v.req("y_dtype")?.as_str()?.to_string(),
            num_classes: v.req("num_classes")?.as_usize()?,
            local_steps,
            eval,
            apply: v.req("apply")?.as_str()?.to_string(),
            apply_momentum: v.req("apply_momentum")?.as_str()?.to_string(),
            init_params: v.req("init_params")?.as_str()?.to_string(),
            init_params_sha256: v.str_or("init_params_sha256", "")?.to_string(),
            jax_version: v.str_or("jax_version", "")?.to_string(),
        })
    }

    /// Serialize back to JSON (CLI `inspect`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("seed", Json::num(self.seed as f64)),
            (
                "params",
                Json::Arr(
                    self.params
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("name", Json::str(p.name.clone())),
                                (
                                    "shape",
                                    Json::Arr(
                                        p.shape.iter().map(|&d| Json::num(d as f64)).collect(),
                                    ),
                                ),
                                ("numel", Json::num(p.numel as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_param_numel", Json::num(self.total_param_numel as f64)),
            ("bytes_per_commit", Json::num(self.bytes_per_commit as f64)),
            ("x_shape", Json::Arr(self.x_shape.iter().map(|&d| Json::num(d as f64)).collect())),
            ("x_dtype", Json::str(self.x_dtype.clone())),
            ("y_shape", Json::Arr(self.y_shape.iter().map(|&d| Json::num(d as f64)).collect())),
            ("y_dtype", Json::str(self.y_dtype.clone())),
            ("num_classes", Json::num(self.num_classes as f64)),
            (
                "local_steps",
                Json::Arr(
                    self.local_steps
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("k", Json::num(e.k as f64)),
                                ("b", Json::num(e.b as f64)),
                                ("file", Json::str(e.file.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "eval",
                Json::obj(vec![
                    ("b", Json::num(self.eval.b as f64)),
                    ("file", Json::str(self.eval.file.clone())),
                ]),
            ),
            ("apply", Json::str(self.apply.clone())),
            ("apply_momentum", Json::str(self.apply_momentum.clone())),
            ("init_params", Json::str(self.init_params.clone())),
            ("jax_version", Json::str(self.jax_version.clone())),
        ])
    }

    /// Structural validation: referenced files exist, param metadata is
    /// self-consistent, the init blob has the right byte length.
    pub fn validate(&self, dir: &Path) -> Result<()> {
        let total: usize = self.params.iter().map(|p| p.numel).sum();
        if total != self.total_param_numel {
            bail!(
                "manifest {}: param numel sum {} != total_param_numel {}",
                self.model, total, self.total_param_numel
            );
        }
        for p in &self.params {
            let numel: usize = p.shape.iter().product::<usize>().max(1);
            if numel != p.numel {
                bail!("manifest {}: param {} shape/numel mismatch", self.model, p.name);
            }
        }
        let mut names: Vec<&str> = self.params.iter().map(|p| p.name.as_str()).collect();
        let sorted = {
            let mut s = names.clone();
            s.sort_unstable();
            s
        };
        if names != sorted {
            bail!("manifest {}: params not in sorted order", self.model);
        }
        names.dedup();
        if names.len() != self.params.len() {
            bail!("manifest {}: duplicate param names", self.model);
        }
        if self.local_steps.is_empty() {
            bail!("manifest {}: no local_steps variants", self.model);
        }
        for v in &self.local_steps {
            let f = dir.join(&v.file);
            if !f.is_file() {
                bail!("manifest {}: missing artifact {f:?}", self.model);
            }
        }
        for f in [&self.eval.file, &self.apply, &self.apply_momentum] {
            if !dir.join(f).is_file() {
                bail!("manifest {}: missing artifact {f}", self.model);
            }
        }
        let init = dir.join(&self.init_params);
        let meta = std::fs::metadata(&init)
            .with_context(|| format!("missing init params {init:?}"))?;
        if meta.len() as usize != 4 * self.total_param_numel {
            bail!(
                "manifest {}: init_params.bin is {} bytes, expected {}",
                self.model, meta.len(), 4 * self.total_param_numel
            );
        }
        Ok(())
    }

    /// Batch sizes available for `local_steps` (sorted ascending, deduped).
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut bs: Vec<usize> = self.local_steps.iter().map(|v| v.b).collect();
        bs.sort_unstable();
        bs.dedup();
        bs
    }

    /// k-variants available for batch size `b` (sorted descending).
    pub fn k_variants(&self, b: usize) -> Vec<usize> {
        let mut ks: Vec<usize> =
            self.local_steps.iter().filter(|v| v.b == b).map(|v| v.k).collect();
        ks.sort_unstable_by(|a, c| c.cmp(a));
        ks
    }

    pub fn variant(&self, k: usize, b: usize) -> Option<&StepVariant> {
        self.local_steps.iter().find(|v| v.k == k && v.b == b)
    }

    /// Decompose `tau` local steps into available scan lengths for batch `b`,
    /// largest-first (e.g. tau=23, ks={16,4,1} → [16,4,1,1,1]).
    pub fn decompose_tau(&self, tau: usize, b: usize) -> Result<Vec<usize>> {
        let ks = self.k_variants(b);
        if ks.is_empty() {
            bail!("model {}: no local_steps variants for batch size {b}", self.model);
        }
        if !ks.contains(&1) {
            bail!("model {}: need a k=1 variant for batch size {b}", self.model);
        }
        let mut rest = tau;
        let mut plan = Vec::new();
        for &k in &ks {
            while rest >= k {
                plan.push(k);
                rest -= k;
            }
        }
        debug_assert_eq!(rest, 0);
        Ok(plan)
    }

    pub fn param_file(&self, dir: &Path) -> PathBuf {
        dir.join(&self.init_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Manifest {
        Manifest {
            model: "m".into(),
            seed: 0,
            params: vec![
                ParamMeta { name: "a/w".into(), shape: vec![2, 3], numel: 6 },
                ParamMeta { name: "b/w".into(), shape: vec![4], numel: 4 },
            ],
            total_param_numel: 10,
            bytes_per_commit: 40,
            x_shape: vec![2],
            x_dtype: "f32".into(),
            y_shape: vec![],
            y_dtype: "i32".into(),
            num_classes: 2,
            local_steps: vec![
                StepVariant { k: 1, b: 8, file: "x".into() },
                StepVariant { k: 4, b: 8, file: "x".into() },
                StepVariant { k: 16, b: 8, file: "x".into() },
                StepVariant { k: 1, b: 32, file: "x".into() },
            ],
            eval: EvalMeta { b: 8, file: "x".into() },
            apply: "x".into(),
            apply_momentum: "x".into(),
            init_params: "x".into(),
            init_params_sha256: String::new(),
            jax_version: String::new(),
        }
    }

    #[test]
    fn decompose_tau_exact() {
        let m = sample_manifest();
        assert_eq!(m.decompose_tau(23, 8).unwrap(), vec![16, 4, 1, 1, 1]);
        assert_eq!(m.decompose_tau(1, 8).unwrap(), vec![1]);
        assert_eq!(m.decompose_tau(16, 8).unwrap(), vec![16]);
        assert_eq!(m.decompose_tau(0, 8).unwrap(), Vec::<usize>::new());
        // Batch 32 only has k=1.
        assert_eq!(m.decompose_tau(3, 32).unwrap(), vec![1, 1, 1]);
    }

    #[test]
    fn decompose_tau_sums() {
        let m = sample_manifest();
        for tau in 0..200 {
            let plan = m.decompose_tau(tau, 8).unwrap();
            assert_eq!(plan.iter().sum::<usize>(), tau);
        }
    }

    #[test]
    fn batch_and_k_queries() {
        let m = sample_manifest();
        assert_eq!(m.batch_sizes(), vec![8, 32]);
        assert_eq!(m.k_variants(8), vec![16, 4, 1]);
        assert!(m.variant(4, 8).is_some());
        assert!(m.variant(4, 32).is_none());
    }
}
