//! PJRT runtime: load the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py`, compile them once, and execute them on the
//! training hot path. Python never runs here.
//!
//! * [`manifest`] — the artifact contract (shapes, dtypes, calling
//!   convention) mirrored from `manifest.json`; validated at load.
//! * [`tensor`] — host-side parameter sets and batches, plus XLA literal
//!   conversion.
//! * [`model`] — [`model::ModelRuntime`]: one compiled-executable cache per
//!   model directory with typed wrappers for `local_steps`, `eval_step`,
//!   `apply_commit` and `apply_commit_momentum`.
//! * [`native`] — pure-rust reference implementations of the PS/worker
//!   update rules, used for cross-validation against the XLA path and as
//!   the simulator's fast apply.

pub mod manifest;
pub mod model;
pub mod native;
pub mod tensor;

pub use manifest::{Manifest, ParamMeta, StepVariant};
pub use model::ModelRuntime;
pub use tensor::{Batch, BatchData, ParamSet};

/// Default artifacts root, overridable with the `ADSP_ARTIFACTS` env var
/// (used by tests and benches so they run from any working directory).
pub fn artifacts_root() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("ADSP_ARTIFACTS") {
        return dir.into();
    }
    // Walk up from the current dir looking for an `artifacts/` directory so
    // `cargo test` / examples work from the repo root or any subdirectory.
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.is_dir() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}
