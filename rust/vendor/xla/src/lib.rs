//! Offline stub of the `xla-rs` PJRT binding surface this project uses.
//!
//! The real crate links libxla/PJRT and executes compiled HLO. That native
//! toolchain is not present in this build environment, so this stub keeps
//! the whole coordinator compiling and lets every artifact-free code path
//! (simulator bookkeeping, sync policies, sharded PS, native apply) run.
//! Host-side [`Literal`] construction and decoding are fully functional;
//! anything that would require the PJRT runtime (`compile`, `execute`)
//! returns a descriptive error. Integration tests and benches already skip
//! when `artifacts/` is absent, so the error paths are never hit in CI.

use std::fmt;
use std::path::Path;

/// Stub error type; converts into `anyhow::Error` at call sites via `?`.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn backend_unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the PJRT/XLA native backend is not available in this offline build \
         (vendored `xla` stub); run on a host with the real xla-rs toolchain"
    ))
}

/// Element types the project marshals (f32 params/inputs, i32 labels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// A host-side literal: element type + dims + raw little-endian bytes.
/// Fully functional (the coordinator builds and decodes these without any
/// native code); only device transfer is stubbed out.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

/// Decodable literal element types (`Literal::to_vec::<T>()`).
pub trait NativeType: Sized + Copy {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Self> {
        let numel: usize = dims.iter().product();
        if numel * ty.byte_width() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} ({numel} elems) does not match {} data bytes",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, requested {:?}", self.ty, T::TY)));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Destructure a tuple literal (only ever produced by execution, which
    /// the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(backend_unavailable("Literal::to_tuple"))
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO-text module (opaque; the stub only checks the file exists).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path:?}: {e}")))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// A device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(backend_unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(backend_unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. Construction succeeds (so artifact-free paths that
/// merely hold a client keep working); compilation reports the stub.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _private: () })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(backend_unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn execution_paths_report_stub() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto { _text: String::new() });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
