//! Vendored, dependency-free subset of the `anyhow` API (this environment
//! has no network access to crates.io, and the coordinator only needs the
//! small surface below: `Result`, `Error`, `Context`, and the `anyhow!` /
//! `bail!` / `ensure!` macros).
//!
//! Semantics match upstream where it matters to callers:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   context chain joined by `": "`.
//! * `Debug` (what `fn main() -> Result<()>` prints on error) shows the
//!   message plus a `Caused by:` chain.
//! * Any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// `Result<T, anyhow::Error>` with an overridable error type, like upstream.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-chain error. The typed source is flattened to text at
/// conversion time — downcasting is not supported (nothing here uses it).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap with an outer context message (what `Context::context` does).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The context chain, outermost first.
    fn chain_msgs(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain_msgs().join(": "))
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain_msgs();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, m) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {m}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the typed source chain into message links.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.expect("at least one message")
    }
}

/// Extension trait adding `.context()` / `.with_context()` to results and
/// options, as upstream.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {args}")` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {args}")` — `bail!` unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Err::<(), _>(io_err()).context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn option_context_and_macros() {
        fn f(x: Option<u32>) -> Result<u32> {
            let v = x.context("missing")?;
            ensure!(v < 10, "too big: {v}");
            if v == 7 {
                bail!("unlucky {v}");
            }
            Ok(v)
        }
        assert_eq!(f(Some(3)).unwrap(), 3);
        assert_eq!(format!("{}", f(None).unwrap_err()), "missing");
        assert_eq!(format!("{}", f(Some(12)).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(Some(7)).unwrap_err()), "unlucky 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }
}
