//! Integration tests over the real AOT artifacts: runtime contract, XLA vs
//! native cross-validation, full simulator runs per synchronization model,
//! and the real-time engine.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use adsp::cluster::{scenarios, ClusterEvent, ClusterTimeline};
use adsp::config::{profiles, ClusterSpec, CohortSpec, Dist, ExperimentSpec, SyncSpec, WorkerSpec};
use adsp::coordinator::RealtimeEngine;
use adsp::data::make_source;
use adsp::run::{Backend, Run, RunObserver, RunReport};
use adsp::runtime::{artifacts_root, native, ModelRuntime};
use adsp::simulation::SimEngine;
use adsp::sync::SyncModelKind;
use adsp::util::Json;

fn have_artifacts(model: &str) -> bool {
    artifacts_root().join(model).join("manifest.json").is_file()
}

macro_rules! require_artifacts {
    ($model:expr) => {
        if !have_artifacts($model) {
            eprintln!("SKIP: artifacts for {} not built (run `make artifacts`)", $model);
            return;
        }
    };
}

fn tiny_spec(model: &str, kind: SyncModelKind) -> ExperimentSpec {
    let cluster = ClusterSpec::new(vec![
        WorkerSpec::new(2.0, 0.2),
        WorkerSpec::new(2.0, 0.2),
        WorkerSpec::new(0.7, 0.2),
    ]);
    let mut sync = SyncSpec::new(kind);
    sync.gamma = 20.0;
    sync.epoch_secs = 120.0;
    sync.eval_window_secs = 15.0;
    sync.tau = 4;
    let mut spec = ExperimentSpec::new(model, cluster, sync);
    spec.batch_size = 32;
    spec.eval_interval_secs = 5.0;
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 3000;
    spec.eta_prime0 = 0.05;
    spec
}

// ---------------------------------------------------------------------------
// runtime contract
// ---------------------------------------------------------------------------

#[test]
fn manifests_load_and_validate_for_all_models() {
    let root = artifacts_root();
    if !root.is_dir() {
        eprintln!("SKIP: no artifacts dir");
        return;
    }
    let mut found = 0;
    for entry in std::fs::read_dir(&root).unwrap() {
        let dir = entry.unwrap().path();
        if dir.join("manifest.json").is_file() {
            let rt = ModelRuntime::load(&dir).unwrap();
            let p = rt.init_params().unwrap();
            assert_eq!(p.total_numel(), rt.manifest.total_param_numel);
            assert!(p.is_finite());
            found += 1;
        }
    }
    assert!(found >= 5, "expected the full model zoo, found {found}");
}

#[test]
fn local_steps_conservation_invariant() {
    // params' + U' == params + U for every leaf (both sides move by ±η′g).
    require_artifacts!("mlp_quick");
    let rt = ModelRuntime::load_by_name("mlp_quick").unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let before: Vec<Vec<f32>> = params
        .leaves
        .iter()
        .zip(&u.leaves)
        .map(|(p, uu)| p.iter().zip(uu).map(|(a, b)| a + b).collect())
        .collect();
    let mut src = make_source(&rt.manifest, 0, 0);
    let (xs, ys) = src.sample_batch(4, 32);
    let losses = rt.local_steps(&mut params, &mut u, &xs, &ys, 0.05).unwrap();
    assert_eq!(losses.len(), 4);
    assert!(losses.iter().all(|l| l.is_finite()));
    for (i, (p, uu)) in params.leaves.iter().zip(&u.leaves).enumerate() {
        for (j, (a, b)) in p.iter().zip(uu).enumerate() {
            let diff = (a + b - before[i][j]).abs();
            assert!(diff < 1e-3, "leaf {i}[{j}] conservation broken: {diff}");
        }
    }
    // U moved.
    assert!(u.l2_norm() > 0.0);
}

#[test]
fn xla_apply_matches_native() {
    require_artifacts!("mlp_quick");
    let rt = ModelRuntime::load_by_name("mlp_quick").unwrap();
    let init = rt.init_params().unwrap();
    let mut u = init.zeros_like();
    for leaf in &mut u.leaves {
        for (i, v) in leaf.iter_mut().enumerate() {
            *v = (i as f32 * 0.37).sin();
        }
    }
    let mut w_xla = init.clone();
    let mut w_native = init.clone();
    rt.apply_commit(&mut w_xla, &u, 0.3).unwrap();
    native::apply_commit(&mut w_native, &u, 0.3);
    assert!(w_xla.max_abs_diff(&w_native) < 1e-5, "XLA and native PS apply disagree");

    // Momentum path.
    let mut v_xla = init.zeros_like();
    let mut v_native = init.zeros_like();
    let mut wm_xla = init.clone();
    let mut wm_native = init.clone();
    for _ in 0..3 {
        rt.apply_commit_momentum(&mut wm_xla, &u, &mut v_xla, 0.2, 0.9).unwrap();
        native::apply_commit_momentum(&mut wm_native, &u, &mut v_native, 0.2, 0.9);
    }
    assert!(wm_xla.max_abs_diff(&wm_native) < 1e-4);
    assert!(v_xla.max_abs_diff(&v_native) < 1e-4);
}

#[test]
fn eval_loss_drops_under_training() {
    require_artifacts!("mlp_quick");
    let rt = ModelRuntime::load_by_name("mlp_quick").unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let mut src = make_source(&rt.manifest, 0, 0);
    let (ex, ey) = src.eval_batch(rt.manifest.eval.b);
    let (loss0, _) = rt.eval(&params, &ex, &ey).unwrap();
    for _ in 0..6 {
        let (xs, ys) = src.sample_batch(16, 32);
        rt.local_steps(&mut params, &mut u, &xs, &ys, 0.05).unwrap();
    }
    let (loss1, acc1) = rt.eval(&params, &ex, &ey).unwrap();
    assert!(loss1 < loss0, "loss did not drop: {loss0} -> {loss1}");
    assert!(acc1 > 0.3, "accuracy still at chance: {acc1}");
}

#[test]
fn local_steps_tau_composes_variants() {
    require_artifacts!("mlp_quick");
    let rt = ModelRuntime::load_by_name("mlp_quick").unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let mut src = make_source(&rt.manifest, 0, 0);
    // tau = 23 → plan [16, 4, 1, 1, 1] at b=32.
    let losses = rt
        .local_steps_tau(&mut params, &mut u, 23, 32, 0.05, |k| src.sample_batch(k, 32))
        .unwrap();
    assert_eq!(losses.len(), 23);
}

#[test]
fn data_sources_exist_for_every_model() {
    for model in ["mlp_quick", "cnn_cifar", "vgg_sim", "rnn_rail", "svm_chiller", "lm_small"] {
        require_artifacts!(model);
        let rt = ModelRuntime::load_by_name(model).unwrap();
        let mut src = make_source(&rt.manifest, 7, 0);
        let (xs, ys) = src.sample_batch(1, rt.manifest.batch_sizes()[0]);
        assert_eq!(xs.dims[0], 1);
        assert_eq!(xs.dims[1], rt.manifest.batch_sizes()[0]);
        assert_eq!(ys.dims[0], 1);
        let (ex, _) = src.eval_batch(rt.manifest.eval.b);
        assert_eq!(ex.dims[0], rt.manifest.eval.b);
    }
}

// ---------------------------------------------------------------------------
// simulator end-to-end per sync model
// ---------------------------------------------------------------------------

#[test]
fn every_sync_model_trains_without_deadlock() {
    require_artifacts!("mlp_quick");
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let out = SimEngine::new(spec).unwrap().run().unwrap();
        assert!(!out.deadlocked(), "{kind} deadlocked");
        assert!(out.total_steps > 0, "{kind} trained no steps");
        assert!(out.total_commits > 0, "{kind} committed nothing");
        let first = out.loss_log.first_loss().unwrap();
        let best = out.best_loss;
        assert!(best < first, "{kind} never improved: {first} -> {best}");
        assert!(out.final_loss.is_finite(), "{kind} diverged");
    }
}

#[test]
fn adsp_keeps_commit_counts_balanced() {
    require_artifacts!("mlp_quick");
    let spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    let commits: Vec<u64> = out.workers.iter().map(|w| w.commits).collect();
    let min = *commits.iter().min().unwrap();
    let max = *commits.iter().max().unwrap();
    // Theorem 2's ε: by any checkpoint the counts stay within a small gap.
    assert!(max - min <= 3, "commit imbalance too large: {commits:?}");
}

#[test]
fn adsp_has_negligible_waiting_bsp_does_not() {
    require_artifacts!("mlp_quick");
    let adsp = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::Adsp))
        .unwrap()
        .run()
        .unwrap();
    let bsp = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::Bsp))
        .unwrap()
        .run()
        .unwrap();
    assert!(
        adsp.breakdown.waiting_fraction() < 0.10,
        "ADSP waiting fraction {:.2} should be negligible",
        adsp.breakdown.waiting_fraction()
    );
    assert!(
        bsp.breakdown.waiting_fraction() > adsp.breakdown.waiting_fraction(),
        "BSP should wait more than ADSP"
    );
}

#[test]
fn bandwidth_accounting_consistent() {
    require_artifacts!("mlp_quick");
    let spec = tiny_spec("mlp_quick", SyncModelKind::Tap);
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    let rt = ModelRuntime::load_by_name("mlp_quick").unwrap();
    // Each commit moves U up and the fresh model down.
    let per_commit = 2 * rt.manifest.bytes_per_commit as u64;
    assert_eq!(out.bytes_total, out.total_commits * per_commit);
    let sum_worker: u64 = out.workers.iter().map(|w| w.bytes_up + w.bytes_down).sum();
    assert_eq!(sum_worker, out.bytes_total);
}

#[test]
fn deterministic_same_seed_same_outcome() {
    require_artifacts!("mlp_quick");
    let a = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::Adsp)).unwrap().run().unwrap();
    let b = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::Adsp)).unwrap().run().unwrap();
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.total_commits, b.total_commits);
    assert_eq!(a.loss_log.samples.len(), b.loss_log.samples.len());
    for (sa, sb) in a.loss_log.samples.iter().zip(&b.loss_log.samples) {
        assert!((sa.loss - sb.loss).abs() < 1e-9, "loss logs diverge");
    }
}

#[test]
fn xla_apply_path_matches_native_path_in_sim() {
    require_artifacts!("mlp_quick");
    let mut e1 = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::FixedAdacomm)).unwrap();
    e1.use_xla_apply = true;
    let a = e1.run().unwrap();
    let b = SimEngine::new(tiny_spec("mlp_quick", SyncModelKind::FixedAdacomm))
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(a.total_steps, b.total_steps);
    let la = a.loss_log.samples.last().unwrap().loss;
    let lb = b.loss_log.samples.last().unwrap().loss;
    assert!((la - lb).abs() < 1e-3, "XLA vs native PS apply drifted: {la} vs {lb}");
}

#[test]
fn svm_and_rnn_models_train_in_sim() {
    for model in ["svm_chiller", "rnn_rail"] {
        require_artifacts!(model);
        let mut spec = tiny_spec(model, SyncModelKind::Adsp);
        spec.batch_size = 128;
        spec.max_total_steps = 600;
        let out = SimEngine::new(spec).unwrap().run().unwrap();
        let first = out.loss_log.first_loss().unwrap();
        assert!(out.best_loss < first, "{model}: {first} -> {}", out.best_loss);
    }
}

#[test]
fn ec2_profile_cluster_runs() {
    require_artifacts!("mlp_quick");
    let cluster = profiles::ec2_cluster(6, 2.0, 0.2);
    let mut sync = SyncSpec::new(SyncModelKind::Adsp);
    sync.gamma = 20.0;
    let mut spec = ExperimentSpec::new("mlp_quick", cluster, sync);
    spec.batch_size = 32;
    spec.max_virtual_secs = 60.0;
    spec.max_total_steps = 2000;
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    assert_eq!(out.workers.len(), 6);
    assert!(out.total_steps > 0);
}

#[test]
fn experiment_spec_json_file_roundtrip() {
    require_artifacts!("mlp_quick");
    let spec = tiny_spec("mlp_quick", SyncModelKind::Ssp);
    let dir = std::env::temp_dir().join("adsp_test_spec");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("spec.json");
    std::fs::write(&path, spec.to_json().dump_pretty()).unwrap();
    let loaded = ExperimentSpec::load(&path).unwrap();
    assert_eq!(loaded.model, "mlp_quick");
    assert_eq!(loaded.sync.kind, SyncModelKind::Ssp);
    assert_eq!(loaded.cluster.m(), 3);
}

// ---------------------------------------------------------------------------
// cluster timelines
// ---------------------------------------------------------------------------

#[test]
fn empty_timeline_bit_identical_for_every_sync_model() {
    // Acceptance pin: the timeline refactor must not perturb the static
    // path. A run with no timeline, and a run whose timeline contains
    // only *no-op* events (a speed re-asserted to its current value, an
    // event past the horizon), must produce bit-identical loss logs and
    // identical counters for every sync model.
    require_artifacts!("mlp_quick");
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let base = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        let mut noop = spec.clone();
        noop.timeline = ClusterTimeline::new(vec![
            ClusterEvent::SpeedChange {
                t: 30.0,
                worker: 0,
                speed: spec.cluster.workers[0].speed,
            },
            ClusterEvent::CommChange { t: 1e9, worker: 1, comm_secs: 99.0 },
        ]);
        let same = SimEngine::new(noop).unwrap().run().unwrap();
        assert_eq!(base.total_steps, same.total_steps, "{kind}: steps diverged");
        assert_eq!(base.total_commits, same.total_commits, "{kind}: commits diverged");
        assert_eq!(
            base.loss_log.samples.len(),
            same.loss_log.samples.len(),
            "{kind}: eval count diverged"
        );
        for (a, b) in base.loss_log.samples.iter().zip(&same.loss_log.samples) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{kind}: loss log diverged at t={}",
                a.t
            );
        }
    }
}

#[test]
fn every_sync_model_survives_churn_timeline() {
    require_artifacts!("mlp_quick");
    for kind in SyncModelKind::ALL {
        let mut spec = tiny_spec("mlp_quick", kind);
        spec.timeline = scenarios::churn(&spec.cluster, 30.0, 60.0, 1);
        let out = SimEngine::new(spec).unwrap().run().unwrap();
        assert!(!out.deadlocked(), "{kind} deadlocked under churn");
        assert!(out.total_steps > 0, "{kind} trained no steps");
        assert!(out.final_loss.is_finite(), "{kind} diverged");
        // One leaver + one joiner: the metrics vector grew by one slot.
        assert_eq!(out.workers.len(), 4, "{kind}: joiner missing from metrics");
    }
}

#[test]
fn joined_worker_trains_from_snapshot() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Tap);
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::WorkerJoin {
        t: 40.0,
        spec: WorkerSpec::new(2.0, 0.2),
    }]);
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    assert_eq!(out.workers.len(), 4);
    let joined = &out.workers[3];
    assert!(joined.steps > 0, "joiner never trained");
    assert!(joined.commits > 0, "joiner never committed");
    // It only lived for part of the run.
    assert!(joined.steps < out.workers[0].steps, "joiner outran a founder");
}

#[test]
fn mid_run_slowdown_shifts_load_not_correctness() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.timeline = scenarios::slowdown(&spec.cluster, 30.0, 4.0);
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    assert!(!out.deadlocked());
    assert!(out.best_loss < out.loss_log.first_loss().unwrap(), "training regressed");
}

// ---------------------------------------------------------------------------
// network model
// ---------------------------------------------------------------------------

#[test]
fn degenerate_network_bit_identical_for_every_sync_model() {
    // Acceptance pin: the link-model refactor must not perturb the
    // static-comm path. A run with no `network` section, and a run whose
    // network is *explicitly* degenerate (zero latency, unbounded
    // bandwidth, no jitter, no ingress cap — per-worker entries
    // included), must produce bit-identical loss logs and identical
    // counters for every sync model.
    require_artifacts!("mlp_quick");
    use adsp::network::{LinkModel, NetworkSpec};
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let base = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        let mut degenerate = spec.clone();
        degenerate.network = NetworkSpec {
            default_link: LinkModel::unbounded(),
            links: vec![LinkModel::unbounded(); spec.cluster.m()],
            ingress_bytes_per_sec: 0.0,
            ingress_discipline: adsp::network::IngressDiscipline::FairShare,
        };
        assert!(degenerate.network.is_static());
        let same = SimEngine::new(degenerate).unwrap().run().unwrap();
        assert_eq!(base.total_steps, same.total_steps, "{kind}: steps diverged");
        assert_eq!(base.total_commits, same.total_commits, "{kind}: commits diverged");
        assert_eq!(base.bytes_total, same.bytes_total, "{kind}: bytes diverged");
        assert_eq!(
            base.loss_log.samples.len(),
            same.loss_log.samples.len(),
            "{kind}: eval count diverged"
        );
        for (a, b) in base.loss_log.samples.iter().zip(&same.loss_log.samples) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{kind}: loss log diverged at t={}",
                a.t
            );
        }
        for (a, b) in base.workers.iter().zip(&same.workers) {
            assert_eq!(
                a.comm_secs.to_bits(),
                b.comm_secs.to_bits(),
                "{kind}: comm accounting diverged"
            );
        }
    }
}

#[test]
fn finite_links_slow_convergence_not_correctness() {
    // A starved per-worker link must stretch commit time (more comm
    // seconds per commit) without breaking training.
    require_artifacts!("mlp_quick");
    use adsp::network::LinkModel;
    let spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    let free = SimEngine::new(spec.clone()).unwrap().run().unwrap();
    let mut starved = spec;
    starved.network.default_link =
        LinkModel { bandwidth_bytes_per_sec: 2e5, latency_secs: 0.05, jitter: 0.0 };
    let slow = SimEngine::new(starved).unwrap().run().unwrap();
    assert!(slow.total_steps > 0);
    assert!(slow.best_loss < slow.loss_log.first_loss().unwrap(), "training regressed");
    let per_commit = |o: &adsp::run::RunReport| {
        let comm: f64 = o.workers.iter().map(|w| w.comm_secs).sum();
        comm / o.total_commits.max(1) as f64
    };
    assert!(
        per_commit(&slow) > per_commit(&free),
        "finite link should cost comm time: {} vs {}",
        per_commit(&slow),
        per_commit(&free)
    );
}

#[test]
fn blackout_defers_commits_and_training_recovers() {
    require_artifacts!("mlp_quick");
    for kind in [SyncModelKind::Adsp, SyncModelKind::Ssp, SyncModelKind::Tap] {
        let mut spec = tiny_spec("mlp_quick", kind);
        // Workers 0 and 2 offline for 30–60s of the 120s run.
        spec.timeline = ClusterTimeline::new(vec![ClusterEvent::CommBlackout {
            start: 30.0,
            duration: 30.0,
            workers: vec![0, 2],
            cell: None,
        }]);
        let out = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        assert!(!out.deadlocked(), "{kind} deadlocked under blackout");
        assert!(out.total_commits > 0, "{kind} never committed");
        assert!(out.best_loss < out.loss_log.first_loss().unwrap(), "{kind} regressed");
        // The blackout actually cost the affected workers comm time.
        let base = SimEngine::new(tiny_spec("mlp_quick", kind)).unwrap().run().unwrap();
        let wait = |o: &adsp::run::RunReport| {
            o.workers.iter().map(|w| w.comm_secs).sum::<f64>()
        };
        assert!(
            wait(&out) > wait(&base),
            "{kind}: blackout added no comm time ({} vs {})",
            wait(&out),
            wait(&base)
        );
    }
}

#[test]
fn ingress_cap_queues_concurrent_commits() {
    require_artifacts!("mlp_quick");
    use adsp::network::IngressDiscipline;
    // TAP commits every step, so a tight aggregate cap must show up as
    // comm time for both disciplines.
    for discipline in [IngressDiscipline::Fifo, IngressDiscipline::FairShare] {
        let spec = tiny_spec("mlp_quick", SyncModelKind::Tap);
        let free = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        let mut capped = spec;
        capped.network.ingress_bytes_per_sec = 2e5;
        capped.network.ingress_discipline = discipline;
        let out = SimEngine::new(capped).unwrap().run().unwrap();
        assert!(out.total_commits > 0);
        let per_commit = |o: &adsp::run::RunReport| {
            o.workers.iter().map(|w| w.comm_secs).sum::<f64>()
                / o.total_commits.max(1) as f64
        };
        assert!(
            per_commit(&out) > per_commit(&free),
            "{discipline:?}: ingress cap added no delay"
        );
    }
}

// ---------------------------------------------------------------------------
// real-time engine
// ---------------------------------------------------------------------------

#[test]
fn realtime_engine_short_run() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 150.0;
    spec.max_total_steps = 1500;
    spec.eval_interval_secs = 10.0;
    // 150 virtual seconds at 0.01 scale ≈ 1.5 wall seconds.
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert!(out.total_commits > 0, "no commits");
    assert!(out.final_loss.is_finite());
    let first = out.loss_log.first_loss().unwrap_or(f64::NAN);
    assert!(out.loss_log.best_loss().unwrap_or(f64::NAN) <= first);
    assert!(out.wall_secs < 30.0, "realtime run took too long: {}", out.wall_secs);
}

#[test]
fn realtime_bsp_barrier_works() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Bsp);
    spec.max_virtual_secs = 80.0;
    spec.max_total_steps = 600;
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    // Lockstep: commit counts within 1 of each other.
    let commits: Vec<u64> = out.workers.iter().map(|w| w.commits).collect();
    let min = *commits.iter().min().unwrap();
    let max = *commits.iter().max().unwrap();
    assert!(max - min <= 2, "BSP commits should be near-lockstep: {commits:?}");
}

#[test]
fn realtime_engine_applies_timeline_churn() {
    // Wall-clock timeline: one worker's speed collapses, another leaves,
    // and a replacement joins mid-run from a PS snapshot. The run must
    // complete with the joiner having trained.
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 150.0;
    spec.max_total_steps = 2000;
    spec.eval_interval_secs = 10.0;
    spec.timeline = ClusterTimeline::new(vec![
        ClusterEvent::SpeedChange { t: 30.0, worker: 0, speed: 0.5 },
        ClusterEvent::WorkerLeave { t: 50.0, worker: 1 },
        ClusterEvent::WorkerJoin { t: 80.0, spec: WorkerSpec::new(2.0, 0.2) },
    ]);
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert_eq!(out.workers.len(), 4, "joiner missing from metrics");
    assert!(out.workers[3].steps > 0, "joiner never trained");
    assert!(out.final_loss.is_finite());
    assert!(out.wall_secs < 30.0, "realtime churn run took too long: {}", out.wall_secs);
}

#[test]
fn realtime_engine_sleeps_link_time_and_survives_blackout() {
    // Wall-clock network model: finite links pad the commit legs and a
    // short blackout holds pushes without wedging any thread.
    require_artifacts!("mlp_quick");
    use adsp::network::LinkModel;
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 1500;
    spec.eval_interval_secs = 10.0;
    spec.network.default_link =
        LinkModel { bandwidth_bytes_per_sec: 5e6, latency_secs: 0.01, jitter: 0.0 };
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::CommBlackout {
        start: 30.0,
        duration: 20.0,
        workers: vec![0],
        cell: None,
    }]);
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert!(out.total_commits > 0, "no commits survived the blackout");
    assert!(out.final_loss.is_finite());
    assert!(out.wall_secs < 30.0, "realtime blackout run took too long: {}", out.wall_secs);
}

// ---------------------------------------------------------------------------
// fault injection, compression, checkpointing
// ---------------------------------------------------------------------------

#[test]
fn step_jitter_changes_timing_not_data() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 60.0;
    let base = SimEngine::new(spec.clone()).unwrap().run().unwrap();
    spec.step_jitter = 0.3;
    let jit = SimEngine::new(spec).unwrap().run().unwrap();
    assert!(!jit.deadlocked());
    assert!(jit.total_steps > 0);
    // Jitter shifts the step timeline.
    assert_ne!(base.total_steps, 0);
    // Losses stay finite and training still progresses.
    assert!(jit.best_loss < jit.loss_log.first_loss().unwrap());
}

#[test]
fn dropped_commits_slow_but_dont_break_training() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Tap);
    spec.max_virtual_secs = 90.0;
    spec.drop_commit_prob = 0.3;
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    assert!(out.dropped_commits() > 0, "fault injection never fired");
    assert!(out.total_commits > 0, "some commits must survive");
    assert!(out.best_loss < out.loss_log.first_loss().unwrap(), "training must still progress");
}

#[test]
fn compression_reduces_bandwidth_and_still_learns() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::FixedAdacomm);
    spec.max_virtual_secs = 90.0;
    let dense = SimEngine::new(spec.clone()).unwrap().run().unwrap();
    spec.compress_topk = 0.1;
    let sparse = SimEngine::new(spec).unwrap().run().unwrap();
    let dense_up: u64 = dense.workers.iter().map(|w| w.bytes_up).sum();
    let sparse_up: u64 = sparse.workers.iter().map(|w| w.bytes_up).sum();
    let per_commit_dense = dense_up as f64 / dense.total_commits.max(1) as f64;
    let per_commit_sparse = sparse_up as f64 / sparse.total_commits.max(1) as f64;
    assert!(
        per_commit_sparse < per_commit_dense * 0.5,
        "top-10% compression should cut upstream bytes: {per_commit_sparse} vs {per_commit_dense}"
    );
    assert!(sparse.best_loss < sparse.loss_log.first_loss().unwrap());
}

// ---------------------------------------------------------------------------
// fault subsystem: crashes, shard failover, checkpoint policies
// ---------------------------------------------------------------------------

#[test]
fn degenerate_fault_config_bit_identical_for_every_sync_model() {
    // Acceptance pin: the fault subsystem must not perturb the pre-fault
    // path. A run with the default (absent) fault section, and a run
    // whose fault section is *explicitly* degenerate (checkpointing off,
    // whatever the sink knobs say), must produce bit-identical loss logs
    // and identical counters for every sync model.
    require_artifacts!("mlp_quick");
    use adsp::fault::{CheckpointPolicy, FaultSpec};
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let base = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        let mut degenerate = spec.clone();
        degenerate.fault = FaultSpec {
            checkpoint: CheckpointPolicy::Off,
            sink_bytes_per_sec: 123.0, // irrelevant while checkpointing is off
            remote_sink: true,
        };
        assert!(degenerate.fault.is_degenerate());
        let same = SimEngine::new(degenerate).unwrap().run().unwrap();
        assert_eq!(base.total_steps, same.total_steps, "{kind}: steps diverged");
        assert_eq!(base.total_commits, same.total_commits, "{kind}: commits diverged");
        assert_eq!(same.wasted_steps, 0, "{kind}: phantom wasted steps");
        assert_eq!(same.checkpoints_taken, 0, "{kind}: phantom checkpoints");
        assert_eq!(
            base.loss_log.samples.len(),
            same.loss_log.samples.len(),
            "{kind}: eval count diverged"
        );
        for (a, b) in base.loss_log.samples.iter().zip(&same.loss_log.samples) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{kind}: loss log diverged at t={}",
                a.t
            );
        }
        for (a, b) in base.workers.iter().zip(&same.workers) {
            assert_eq!(
                a.comm_secs.to_bits(),
                b.comm_secs.to_bits(),
                "{kind}: comm accounting diverged"
            );
        }
    }
}

#[test]
fn worker_crash_loses_work_then_recovers() {
    // An unclean mid-run crash must cost wasted steps, keep the run
    // deadlock-free for blocking and non-blocking policies alike, and let
    // the restarted worker train again after its outage.
    require_artifacts!("mlp_quick");
    for kind in [SyncModelKind::Adsp, SyncModelKind::Ssp, SyncModelKind::Bsp] {
        let mut spec = tiny_spec("mlp_quick", kind);
        // Run to the horizon (no early convergence stop) so both scripted
        // crashes actually fire.
        spec.convergence_window = 10_000;
        // Crash the straggler (never parked at a barrier, so it is
        // mid-chunk or mid-commit with near-certainty) and later a fast
        // worker, on disjoint outage windows.
        spec.timeline = ClusterTimeline::new(vec![
            ClusterEvent::WorkerCrash { t: 40.0, worker: 2, restart_after: 20.0 },
            ClusterEvent::WorkerCrash { t: 75.0, worker: 0, restart_after: 20.0 },
        ]);
        let out = SimEngine::new(spec).unwrap().run().unwrap();
        assert!(!out.deadlocked(), "{kind} deadlocked across the crashes");
        assert!(out.wasted_steps > 0, "{kind}: crashes wasted no work");
        assert!(out.total_commits > 0, "{kind}: cluster stopped committing");
        assert!(out.final_loss.is_finite(), "{kind} diverged");
        assert!(out.best_loss < out.loss_log.first_loss().unwrap(), "{kind} regressed");
        // The victims stayed on the books across their restarts.
        assert!(out.workers[2].steps > 0, "{kind}: crashed worker never trained");
    }
}

#[test]
fn shard_failure_rolls_back_to_checkpoint_and_recovers() {
    require_artifacts!("mlp_quick");
    use adsp::fault::CheckpointPolicy;
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    // Run to the horizon so the scripted failure and at least two interval
    // checkpoints are guaranteed to fire.
    spec.convergence_window = 10_000;
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::ShardFailure {
        t: 70.0,
        shard: 0,
        recover_after: 15.0,
    }]);
    spec.fault.checkpoint = CheckpointPolicy::IntervalSecs(25.0);
    spec.fault.sink_bytes_per_sec = 5e4;
    let out = SimEngine::new(spec).unwrap().run().unwrap();
    assert!(out.checkpoints_taken >= 2, "interval policy never fired");
    assert!(out.checkpoint_overhead_secs > 0.0, "checkpoint cost must be visible");
    assert!(out.lost_commits > 0, "failover lost nothing — commits were applied before it");
    assert!(out.wasted_steps > 0, "rolled-back commits must count as wasted work");
    assert!(!out.deadlocked());
    assert!(out.final_loss.is_finite());
    assert!(out.best_loss < out.loss_log.first_loss().unwrap(), "training regressed");
}

#[test]
fn commit_count_checkpoints_fire_and_shorter_intervals_cost_more() {
    require_artifacts!("mlp_quick");
    use adsp::fault::CheckpointPolicy;
    // Commit-count policy fires as commits accumulate.
    let mut by_commits = tiny_spec("mlp_quick", SyncModelKind::Tap);
    by_commits.convergence_window = 10_000;
    by_commits.fault.checkpoint = CheckpointPolicy::EveryCommits(20);
    by_commits.fault.sink_bytes_per_sec = 1e5;
    let out = SimEngine::new(by_commits).unwrap().run().unwrap();
    assert!(out.checkpoints_taken > 0, "commit-count policy never fired");
    assert!(out.total_commits >= 20 * out.checkpoints_taken);
    // Interval policy: halving the interval at least doesn't reduce the
    // checkpoint count, and costs at least as much overhead.
    let run_interval = |secs: f64| {
        let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
        spec.convergence_window = 10_000;
        spec.fault.checkpoint = CheckpointPolicy::IntervalSecs(secs);
        spec.fault.sink_bytes_per_sec = 5e4;
        SimEngine::new(spec).unwrap().run().unwrap()
    };
    let short = run_interval(15.0);
    let long = run_interval(45.0);
    assert!(short.checkpoints_taken > long.checkpoints_taken);
    assert!(short.checkpoint_overhead_secs > long.checkpoint_overhead_secs);
}

#[test]
fn crash_storm_scenario_runs_for_every_compared_model() {
    require_artifacts!("mlp_quick");
    for kind in [SyncModelKind::Adsp, SyncModelKind::Ssp, SyncModelKind::Adacomm] {
        let mut spec = tiny_spec("mlp_quick", kind);
        spec.convergence_window = 10_000;
        spec.timeline =
            scenarios::preset("crash_storm", &spec.cluster, spec.max_virtual_secs).unwrap();
        let out = SimEngine::new(spec).unwrap().run().unwrap();
        assert!(!out.deadlocked(), "{kind} deadlocked in crash_storm");
        assert!(out.wasted_steps > 0, "{kind}: storm wasted no work");
        assert!(out.total_steps > 0 && out.final_loss.is_finite());
    }
}

#[test]
fn realtime_engine_survives_crash_and_restart() {
    // Wall-clock crash semantics: the victim's thread exits, its commit
    // in flight is dropped, and the scheduler respawns it from a PS
    // snapshot after the outage.
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 150.0;
    spec.max_total_steps = 2000;
    spec.eval_interval_secs = 10.0;
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::WorkerCrash {
        t: 40.0,
        worker: 0,
        restart_after: 30.0,
    }]);
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert!(out.total_commits > 0, "no commits survived the crash");
    assert!(out.final_loss.is_finite());
    assert!(out.wall_secs < 30.0, "realtime crash run took too long: {}", out.wall_secs);
}

#[test]
fn realtime_engine_restores_checkpoint_on_shard_failure() {
    require_artifacts!("mlp_quick");
    use adsp::fault::CheckpointPolicy;
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 1500;
    spec.eval_interval_secs = 10.0;
    spec.fault.checkpoint = CheckpointPolicy::IntervalSecs(20.0);
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::ShardFailure {
        t: 50.0,
        shard: 0,
        recover_after: 10.0,
    }]);
    let out = RealtimeEngine::new(spec, 0.01).run().unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert!(out.total_commits > 0, "no commits after failover");
    assert!(out.final_loss.is_finite());
    assert!(out.wall_secs < 30.0, "realtime failover run took too long: {}", out.wall_secs);
}

#[test]
fn checkpoint_save_and_resume() {
    require_artifacts!("mlp_quick");
    let dir = std::env::temp_dir().join("adsp_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("global.params");

    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 60.0;
    let mut engine = SimEngine::new(spec.clone()).unwrap();
    engine.checkpoint_path = Some(ckpt.clone());
    engine.checkpoint_every = 20.0;
    let first = engine.run().unwrap();
    assert!(ckpt.is_file(), "checkpoint not written");

    // Resume: loss starts near where the first run ended, not at init.
    let mut engine2 = SimEngine::new(spec).unwrap();
    engine2.load_initial_params(&ckpt).unwrap();
    let resumed = engine2.run().unwrap();
    let init_loss = first.loss_log.first_loss().unwrap();
    let resumed_start = resumed.loss_log.first_loss().unwrap();
    assert!(
        resumed_start < init_loss * 0.8,
        "resume should start from trained params: {resumed_start} vs init {init_loss}"
    );
}

// ---------------------------------------------------------------------------
// unified run API: builder bit-identity, observer streaming, sim/realtime
// report parity
// ---------------------------------------------------------------------------

/// Observer that counts every callback — used both to verify streaming and
/// to prove an attached observer changes nothing.
#[derive(Default)]
struct CountingObserver {
    evals: usize,
    commits_applied: u64,
    last_commit_count: u64,
    cluster_events: usize,
    checkpoints: u64,
}

impl RunObserver for CountingObserver {
    fn on_eval(&mut self, _t: f64, _steps: u64, _loss: f64, _acc: f64) {
        self.evals += 1;
    }
    fn on_commit_applied(&mut self, _t: f64, _worker: usize, total_commits: u64) {
        self.commits_applied += 1;
        self.last_commit_count = total_commits;
    }
    fn on_cluster_event(&mut self, _t: f64, _event: &ClusterEvent) {
        self.cluster_events += 1;
    }
    fn on_checkpoint(&mut self, _t: f64, _version: u64) {
        self.checkpoints += 1;
    }
}

/// Bit-level equality of everything the simulator computes (the acceptance
/// pin for the run-API migration: the builder path and an attached observer
/// must not perturb a single bit of the report).
fn assert_reports_bit_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.total_steps, b.total_steps, "{tag}: steps diverged");
    assert_eq!(a.total_commits, b.total_commits, "{tag}: commits diverged");
    assert_eq!(a.bytes_total, b.bytes_total, "{tag}: bytes diverged");
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits(), "{tag}: end time diverged");
    assert_eq!(
        a.converged_at.map(f64::to_bits),
        b.converged_at.map(f64::to_bits),
        "{tag}: convergence time diverged"
    );
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{tag}: final loss");
    assert_eq!(a.best_loss.to_bits(), b.best_loss.to_bits(), "{tag}: best loss");
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{tag}: final accuracy"
    );
    assert_eq!(a.wasted_steps, b.wasted_steps, "{tag}: wasted steps");
    assert_eq!(a.lost_commits, b.lost_commits, "{tag}: lost commits");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{tag}: checkpoints");
    assert_eq!(
        a.checkpoint_overhead_secs.to_bits(),
        b.checkpoint_overhead_secs.to_bits(),
        "{tag}: checkpoint overhead"
    );
    assert_eq!(a.loss_log.samples.len(), b.loss_log.samples.len(), "{tag}: eval count");
    for (x, y) in a.loss_log.samples.iter().zip(&b.loss_log.samples) {
        assert_eq!(x.t.to_bits(), y.t.to_bits(), "{tag}: eval time diverged");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{tag}: loss log diverged");
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{tag}: accuracy log");
        assert_eq!(x.total_steps, y.total_steps, "{tag}: step log diverged");
    }
    assert_eq!(a.workers.len(), b.workers.len(), "{tag}: worker count");
    for (x, y) in a.workers.iter().zip(&b.workers) {
        assert_eq!(x.steps, y.steps, "{tag}: worker steps");
        assert_eq!(x.commits, y.commits, "{tag}: worker commits");
        assert_eq!(x.bytes_up, y.bytes_up, "{tag}: worker bytes up");
        assert_eq!(x.bytes_down, y.bytes_down, "{tag}: worker bytes down");
        assert_eq!(x.compute_secs.to_bits(), y.compute_secs.to_bits(), "{tag}: compute");
        assert_eq!(x.comm_secs.to_bits(), y.comm_secs.to_bits(), "{tag}: comm");
        assert_eq!(x.blocked_secs.to_bits(), y.blocked_secs.to_bits(), "{tag}: blocked");
    }
    assert_eq!(a.sync, b.sync, "{tag}: sync kind");
    assert_eq!(a.sync_describe, b.sync_describe, "{tag}: sync describe");
}

#[test]
fn builder_sim_reports_bit_identical_to_direct_engine_for_all_policies() {
    // The acceptance pin: for every sync policy, Backend::Sim through the
    // Run builder reports bit-identically to the engine the pre-refactor
    // run_sim path constructed directly — and attaching an observer (a
    // read-only tap) changes nothing either, while its stream counts match
    // the report's own counters.
    require_artifacts!("mlp_quick");
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let direct = SimEngine::new(spec.clone()).unwrap().run().unwrap();
        let built = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
        assert_reports_bit_identical(&direct, &built, kind.name());
        assert_eq!(built.backend_name(), "sim");

        let mut counter = CountingObserver::default();
        let observed =
            Run::from_spec(spec).observer(&mut counter).execute().unwrap();
        assert_reports_bit_identical(&direct, &observed, kind.name());
        assert_eq!(
            counter.evals,
            observed.loss_log.samples.len(),
            "{kind}: observer missed evals"
        );
        assert_eq!(
            counter.commits_applied, observed.total_commits,
            "{kind}: observer missed commits"
        );
        assert_eq!(
            counter.last_commit_count, observed.total_commits,
            "{kind}: commit counter stream inconsistent"
        );
        assert_eq!(counter.cluster_events, 0, "{kind}: phantom cluster events");
    }
}

#[test]
fn observer_streams_cluster_events_and_checkpoints() {
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.convergence_window = 10_000; // run to the horizon
    spec.timeline = ClusterTimeline::new(vec![
        ClusterEvent::SpeedChange { t: 30.0, worker: 0, speed: 0.5 },
        ClusterEvent::WorkerCrash { t: 60.0, worker: 2, restart_after: 15.0 },
    ]);
    spec.fault.checkpoint = adsp::fault::CheckpointPolicy::IntervalSecs(25.0);
    let mut counter = CountingObserver::default();
    let report = Run::from_spec(spec).observer(&mut counter).execute().unwrap();
    assert_eq!(counter.cluster_events, 2, "both timeline events must stream");
    assert_eq!(
        counter.checkpoints, report.checkpoints_taken,
        "checkpoint stream must match the report counter"
    );
    assert!(counter.checkpoints >= 2, "interval checkpoints never streamed");
    assert_eq!(counter.evals, report.loss_log.samples.len());
}

#[test]
fn sim_and_realtime_reports_populate_the_same_field_set() {
    // Field-parity acceptance: the same spec through both backends yields
    // reports with the identical JSON schema, and the realtime report has
    // no permanently-empty fields (best_loss, accuracy, describe, bytes —
    // the gaps the old RealtimeOutcome left).
    require_artifacts!("mlp_quick");
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 1200;
    spec.eval_interval_secs = 10.0;
    let sim = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
    let rt = Run::from_spec(spec)
        .backend(Backend::Realtime { time_scale: 0.01 })
        .execute()
        .unwrap();

    let keys = |r: &RunReport| -> Vec<String> {
        match r.to_json() {
            Json::Obj(m) => m.keys().cloned().collect(),
            _ => panic!("report JSON must be an object"),
        }
    };
    assert_eq!(keys(&sim), keys(&rt), "sim and realtime schemas diverged");

    assert_eq!(rt.backend_name(), "realtime");
    assert_eq!(rt.sync, SyncModelKind::Adsp);
    assert!(!rt.sync_describe.is_empty(), "realtime dropped sync_describe");
    assert!(rt.best_loss.is_finite(), "realtime dropped best_loss");
    assert!(rt.final_accuracy.is_finite(), "realtime dropped final accuracy");
    assert!(rt.bytes_total > 0, "realtime dropped byte accounting");
    assert!(rt.wall_secs > 0.0 && rt.end_time > 0.0);
    assert!(!rt.workers.is_empty());
    assert!(rt.wall_secs < 30.0, "realtime parity run took too long: {}", rt.wall_secs);
}

#[test]
fn realtime_report_tracks_fault_counters() {
    // Parity fix pin: the realtime engine must populate the fault counters
    // the old outcome type dropped — checkpoints taken (with a measured
    // overhead) and, across a crash + shard failure, lost work.
    require_artifacts!("mlp_quick");
    use adsp::fault::CheckpointPolicy;
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 150.0;
    spec.max_total_steps = 2000;
    spec.eval_interval_secs = 10.0;
    spec.fault.checkpoint = CheckpointPolicy::IntervalSecs(20.0);
    spec.timeline = ClusterTimeline::new(vec![
        ClusterEvent::WorkerCrash { t: 40.0, worker: 2, restart_after: 20.0 },
        ClusterEvent::ShardFailure { t: 90.0, shard: 0, recover_after: 10.0 },
    ]);
    let report = Run::from_spec(spec)
        .backend(Backend::Realtime { time_scale: 0.01 })
        .execute()
        .unwrap();
    assert!(report.checkpoints_taken >= 1, "interval checkpoints never counted");
    assert!(
        report.checkpoint_overhead_secs > 0.0,
        "checkpoint cost must be measured"
    );
    // The crash loses uncommitted steps and the failover rolls back
    // commits; thread timing makes the exact split nondeterministic, but
    // the run as a whole must have lost *something*.
    assert!(
        report.wasted_steps + report.lost_commits > 0,
        "crash + shard failure lost no work"
    );
    assert!(report.total_commits > 0 && report.final_loss.is_finite());
    assert!(report.wall_secs < 30.0, "realtime fault run took too long");
}

#[test]
fn run_report_json_dump_round_trips_through_files() {
    // The `--out report.json` path: dump a real sim report, parse it back,
    // and the JSON forms match exactly.
    require_artifacts!("mlp_quick");
    let report = Run::from_spec(tiny_spec("mlp_quick", SyncModelKind::Tap))
        .execute()
        .unwrap();
    let dir = std::env::temp_dir().join("adsp_report_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("report.json");
    std::fs::write(&path, report.to_json().dump_pretty()).unwrap();
    let back = RunReport::from_json_str(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(back.to_json(), report.to_json(), "report JSON round trip drifted");
    assert_eq!(back.backend_name(), "sim");
    assert_eq!(back.total_steps, report.total_steps);
    assert_eq!(back.loss_log.samples.len(), report.loss_log.samples.len());
}

// ---------------------------------------------------------------------------
// observability subsystem
// ---------------------------------------------------------------------------

#[test]
fn obs_disabled_and_enabled_sim_runs_bit_identical_for_all_policies() {
    // The observability acceptance pin: attaching a full ObsHub (metrics +
    // trace) must not perturb one bit of the simulator's output for any
    // sync policy — taps never draw RNG and never touch engine state. The
    // observed run must additionally populate RunReport::metrics and the
    // trace ring, with the eval counter agreeing with the loss log.
    require_artifacts!("mlp_quick");
    use adsp::obs::{ObsConfig, ObsHub};
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("mlp_quick", kind);
        let plain = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
        assert!(plain.metrics.is_none(), "{kind}: metrics without a hub");

        let hub = ObsHub::new(ObsConfig { metrics: true, trace_capacity: Some(4096), spans: false });
        let observed = Run::from_spec(spec)
            .backend(Backend::Sim)
            .observability(&hub)
            .execute()
            .unwrap();
        assert_reports_bit_identical(&plain, &observed, kind.name());

        let metrics = observed.metrics.as_ref().expect("observed run lost its metrics");
        assert_eq!(
            metrics.counter("sim/evals"),
            observed.loss_log.samples.len() as u64,
            "{kind}: eval counter disagrees with the loss log"
        );
        assert!(
            metrics.counter("net/commits_sent") >= observed.total_commits,
            "{kind}: sent fewer commits than were applied"
        );
        assert!(hub.trace_len() > 0, "{kind}: trace ring stayed empty");
    }
}

#[test]
fn same_seed_sim_runs_produce_identical_metrics_snapshots() {
    // Determinism of the metrics themselves: two same-seed sim runs must
    // produce bit-equal deterministic views (counters, gauges, histogram
    // buckets) — only the wall/ namespace may differ between runs. The
    // snapshot must also survive a JSON round trip unchanged.
    require_artifacts!("mlp_quick");
    use adsp::obs::{MetricsRegistry, ObsConfig, ObsHub};
    let run_once = || {
        let hub = ObsHub::new(ObsConfig { metrics: true, trace_capacity: None, spans: false });
        let report = Run::from_spec(tiny_spec("mlp_quick", SyncModelKind::Adsp))
            .backend(Backend::Sim)
            .observability(&hub)
            .execute()
            .unwrap();
        report.metrics.expect("metrics missing")
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(
        a.deterministic_view(),
        b.deterministic_view(),
        "same-seed runs disagree outside wall/"
    );
    let back = MetricsRegistry::from_json(&a.to_json()).unwrap();
    assert_eq!(back, a, "metrics snapshot JSON round trip drifted");
    // The wall/ namespace exists (handling time is recorded) but is
    // stripped from the deterministic view.
    assert!(a.deterministic_view().histograms().keys().all(|k| !k.starts_with("wall/")));
}

#[test]
fn realtime_run_populates_metrics_and_trace() {
    // The realtime engine feeds the same hub surface: per-shard PS apply
    // histograms, commit round-trip latency, byte counters, and a
    // time-ordered trace stream bracketed by run_start / run_end.
    require_artifacts!("mlp_quick");
    use adsp::obs::{ObsConfig, ObsHub, TraceRecorder};
    let mut spec = tiny_spec("mlp_quick", SyncModelKind::Adsp);
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 1200;
    spec.eval_interval_secs = 10.0;
    spec.shards = 2;
    let hub = ObsHub::new(ObsConfig { metrics: true, trace_capacity: Some(4096), spans: false });
    let report = Run::from_spec(spec)
        .backend(Backend::Realtime { time_scale: 0.01 })
        .observability(&hub)
        .execute()
        .unwrap();

    let metrics = report.metrics.as_ref().expect("realtime run lost its metrics");
    assert_eq!(
        metrics.counter("realtime/evals"),
        report.loss_log.samples.len() as u64,
        "eval counter disagrees with the loss log"
    );
    assert_eq!(
        metrics.counter("realtime/commits_applied"),
        report.total_commits,
        "commit counter disagrees with the report"
    );
    let rtt = metrics.histogram("realtime/commit_rtt_secs").expect("no commit RTT histogram");
    assert!(rtt.count() > 0 && rtt.sum() > 0.0, "commit RTT never observed");
    let shard0 = metrics.histogram("ps/shard0/apply_secs").expect("no shard apply histogram");
    assert!(shard0.count() > 0, "shard 0 never timed an apply");
    assert!(metrics.counter("ps/commits") > 0, "PS commit counter empty");

    // Trace: write, parse back, and check ordering + bracketing.
    let dir = std::env::temp_dir().join("adsp_obs_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("realtime_trace.jsonl");
    let n = hub.write_trace_jsonl(&path).unwrap();
    assert!(n > 0, "trace file empty");
    let events = TraceRecorder::parse_jsonl(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(events.len(), n);
    assert_eq!(events.first().unwrap().kind, "run_start");
    assert_eq!(events.last().unwrap().kind, "run_end");
    for pair in events.windows(2) {
        assert!(pair[0].t <= pair[1].t, "trace not time-ordered: {} > {}", pair[0].t, pair[1].t);
    }
    assert!(report.wall_secs < 30.0, "realtime obs run took too long");
}

#[test]
fn span_enabled_sim_runs_stay_bit_identical_for_all_policies() {
    // The lineage tap extends the obs acceptance pin: arming spans (which
    // ride the trace ring) must not perturb one bit of the simulator's
    // output — including the attribution ledger — while the trace gains
    // parent-linked spans that assemble into complete commit lineages.
    // fleet_proxy needs no artifacts, so this runs on every checkout.
    use adsp::obs::{CommitLineage, ObsConfig, ObsHub, Span, SpanPhase};
    for kind in SyncModelKind::ALL {
        let spec = tiny_spec("fleet_proxy", kind);
        let plain = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
        let hub =
            ObsHub::new(ObsConfig { metrics: false, trace_capacity: Some(1 << 16), spans: true });
        let traced = Run::from_spec(spec)
            .backend(Backend::Sim)
            .observability(&hub)
            .execute()
            .unwrap();
        assert_reports_bit_identical(&plain, &traced, kind.name());
        assert_eq!(
            plain.attribution.as_ref().map(|a| a.to_json()),
            traced.attribution.as_ref().map(|a| a.to_json()),
            "{kind}: span tap perturbed the attribution ledger"
        );

        let spans: Vec<Span> = hub
            .with_trace(|tr| {
                tr.events()
                    .filter(|e| e.kind == "span")
                    .map(|e| Span::from_trace_event(e).unwrap())
                    .collect()
            })
            .unwrap();
        assert!(!spans.is_empty(), "{kind}: spans armed but none recorded");
        let has = |p: SpanPhase| spans.iter().any(|s| s.phase == p);
        assert!(has(SpanPhase::Compute), "{kind}: no compute spans");
        assert!(has(SpanPhase::Uplink), "{kind}: no uplink spans");
        assert!(has(SpanPhase::Apply), "{kind}: no apply spans");
        let lineages = CommitLineage::collect(&spans);
        assert!(!lineages.is_empty(), "{kind}: no commit lineages assembled");
        for l in &lineages {
            assert!(l.t1() >= l.t0(), "{kind}: lineage runs backwards");
            assert!(l.wait_secs() >= 0.0, "{kind}: negative lineage wait");
        }
    }
}

#[test]
fn chrome_trace_export_round_trips_through_a_real_run() {
    // End-to-end Perfetto path: record a span-enabled run, export with
    // `write_chrome_trace`, and the file must parse as trace-event JSON
    // whose non-metadata entry count equals the recorded event count.
    use adsp::obs::{export, ObsConfig, ObsHub};
    let hub =
        ObsHub::new(ObsConfig { metrics: false, trace_capacity: Some(1 << 16), spans: true });
    let report = Run::from_spec(tiny_spec("fleet_proxy", SyncModelKind::Adsp))
        .backend(Backend::Sim)
        .observability(&hub)
        .execute()
        .unwrap();
    assert!(report.total_commits > 0, "run produced no commits to trace");
    let events: Vec<_> = hub.with_trace(|tr| tr.events().cloned().collect::<Vec<_>>()).unwrap();
    assert!(!events.is_empty());

    let dir = std::env::temp_dir().join("adsp_chrome_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.chrome.json");
    let written = export::write_chrome_trace(&path, &events).unwrap();
    assert_eq!(written, events.len(), "exporter dropped or invented entries");
    let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(
        export::chrome_event_count(&back).unwrap(),
        events.len(),
        "chrome trace event count did not round-trip"
    );
}

// ---------------------------------------------------------------------------
// fleet scale: cohorts, streaming aggregation, fleet_proxy runtime
// ---------------------------------------------------------------------------
//
// The fleet_proxy model needs no compiled artifacts (its loss is a pure
// function of the global step counter), so unlike the mlp_quick tests above
// these run unconditionally — they are tier-1's only full-engine coverage
// on an artifact-free checkout.

/// A small cohort fleet on a short horizon (the fig17 shape, sized for
/// tier-1).
fn fleet_test_spec(kind: SyncModelKind, n: usize) -> ExperimentSpec {
    let cohort = CohortSpec::new(
        n,
        Dist::LogNormal { median: 1.5, sigma: 0.4 },
        Dist::Uniform { lo: 0.1, hi: 0.3 },
    );
    let cluster = ClusterSpec::new(Vec::new()).with_cohorts(vec![cohort]);
    let mut sync = SyncSpec::new(kind);
    sync.gamma = 20.0;
    sync.epoch_secs = 120.0;
    sync.eval_window_secs = 15.0;
    sync.tau = 4;
    let mut spec = ExperimentSpec::new("fleet_proxy", cluster, sync);
    spec.batch_size = 32;
    spec.eval_interval_secs = 10.0;
    spec.max_virtual_secs = 40.0;
    spec.max_total_steps = (n as u64) * 200;
    spec
}

#[test]
fn degenerate_cohort_run_bit_identical_to_explicit_workers() {
    // Acceptance pin: a cohort of point distributions is pure spec-sugar.
    // For every sync policy, running the cohort form must reproduce the
    // hand-expanded worker list's run bit for bit — same loss log, same
    // counters, same per-worker metrics.
    for kind in SyncModelKind::ALL {
        let explicit = tiny_spec("fleet_proxy", kind);
        let mut cohorted = explicit.clone();
        cohorted.cluster = ClusterSpec::new(Vec::new()).with_cohorts(vec![
            CohortSpec::new(2, Dist::Point(2.0), Dist::Point(0.2)),
            CohortSpec::new(1, Dist::Point(0.7), Dist::Point(0.2)),
        ]);
        let a = Run::from_spec(explicit).backend(Backend::Sim).execute().unwrap();
        let b = Run::from_spec(cohorted).backend(Backend::Sim).execute().unwrap();
        assert_reports_bit_identical(&a, &b, &format!("cohort sugar under {}", kind.name()));
        assert!(a.events_processed() > 0, "{}: no events counted", kind.name());
        assert_eq!(
            a.events_processed(),
            b.events_processed(),
            "{}: event counts diverged",
            kind.name()
        );
    }
}

#[test]
fn worker_metrics_cap_gates_materialization_not_results() {
    // Above the cap the report must stream its aggregates (empty `workers`
    // vector) without perturbing a single computed bit relative to the
    // materializing run of the identical spec.
    let mut streamed = fleet_test_spec(SyncModelKind::Adsp, 48);
    streamed.worker_metrics_cap = 16;
    let mut materialized = streamed.clone();
    materialized.worker_metrics_cap = 1 << 20;

    let a = Run::from_spec(streamed).backend(Backend::Sim).execute().unwrap();
    let b = Run::from_spec(materialized).backend(Backend::Sim).execute().unwrap();

    assert!(a.workers.is_empty(), "cap ignored: per-worker metrics materialized");
    assert_eq!(b.workers.len(), 48, "uncapped run lost its per-worker metrics");
    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.total_commits, b.total_commits);
    assert_eq!(a.bytes_total, b.bytes_total);
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits());
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.events_processed(), b.events_processed());
    // The streamed breakdown folds worker-by-worker without the vector and
    // must land on the identical averages.
    assert_eq!(a.breakdown.avg_compute_secs.to_bits(), b.breakdown.avg_compute_secs.to_bits());
    assert_eq!(a.breakdown.avg_waiting_secs.to_bits(), b.breakdown.avg_waiting_secs.to_bits());
    assert_eq!(a.breakdown.avg_comm_secs.to_bits(), b.breakdown.avg_comm_secs.to_bits());
    assert_eq!(a.breakdown.avg_blocked_secs.to_bits(), b.breakdown.avg_blocked_secs.to_bits());
    assert!(a.breakdown.avg_compute_secs.is_finite());
    assert!(a.total_steps > 0 && a.events_processed() > 0);
}

#[test]
fn cell_crash_timeline_expands_and_recovers() {
    // A cell-targeted crash against cohort members: expansion rewrites it
    // into per-member WorkerCrash events, the run loses the in-flight work
    // of that cell, and training continues after the restart.
    let mut spec = fleet_test_spec(SyncModelKind::Adsp, 12);
    spec.cluster.cohorts[0].cells = vec!["cell-a".into(), "cell-b".into()];
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::CellCrash {
        t: 10.0,
        cell: "cell-a".into(),
        restart_after: 5.0,
    }]);
    spec.validate().unwrap();

    let report = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
    assert!(report.total_steps > 0, "fleet never trained through the cell crash");
    assert!(report.wasted_steps > 0, "crashing half the fleet wasted no work");
    assert!(report.end_time > 10.0, "run ended before the crash fired");

    // The spec-level expansion carries one WorkerCrash per cell member.
    let expanded = spec.expanded().unwrap().unwrap();
    let crashes = expanded
        .timeline
        .events()
        .iter()
        .filter(|e| matches!(e, ClusterEvent::WorkerCrash { .. }))
        .count();
    assert_eq!(crashes, 6, "cell-a holds every other of 12 members");
}

#[test]
fn fleet_proxy_losses_decrease_and_report_parses() {
    // End-to-end sanity for the artifact-free runtime: losses decay with
    // steps, the report round-trips through JSON with the events_processed
    // counter intact, and the sim stays deterministic across runs.
    let spec = fleet_test_spec(SyncModelKind::Adsp, 24);
    let a = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
    let b = Run::from_spec(spec).backend(Backend::Sim).execute().unwrap();
    assert_reports_bit_identical(&a, &b, "fleet_proxy determinism");

    let first = a.loss_log.samples.first().expect("no evals").loss;
    let last = a.loss_log.samples.last().unwrap().loss;
    assert!(last < first, "synthetic loss failed to decay: {first} -> {last}");

    let back = RunReport::from_json_str(&a.to_json().dump_pretty()).unwrap();
    assert_eq!(back.events_processed(), a.events_processed());
    assert_eq!(back.to_json(), a.to_json(), "fleet report JSON drifted");
}

// ---------------------------------------------------------------------------
// hierarchical fog aggregation tier
// ---------------------------------------------------------------------------
//
// All on the artifact-free fleet_proxy runtime, so the fog tier has full
// tier-1 coverage on every checkout.

use adsp::hierarchy::{AggDownMode, CellAggSpec, FlushPolicy, HierarchySpec};

/// The three-worker spec with cells assigned: workers 0 and 1 in
/// `edge-a`, worker 2 in `edge-b`.
fn celled_spec(kind: SyncModelKind) -> ExperimentSpec {
    let mut spec = tiny_spec("fleet_proxy", kind);
    spec.cluster.workers[0].cell = "edge-a".into();
    spec.cluster.workers[1].cell = "edge-a".into();
    spec.cluster.workers[2].cell = "edge-b".into();
    spec
}

/// A real (non-degenerate) fog tier over both cells: combine every 2
/// member commits, nonzero trunk overhead.
fn fog_section() -> HierarchySpec {
    HierarchySpec {
        cells: vec![CellAggSpec::new("edge-a"), CellAggSpec::new("edge-b")],
        default_comm_secs: 0.3,
        default_flush: Some(FlushPolicy::EveryK(2)),
        ..HierarchySpec::default()
    }
}

#[test]
fn degenerate_hierarchy_bit_identical_for_every_sync_model() {
    // Acceptance pin: the fog tier must not perturb the flat path. A run
    // with no `hierarchy` section, and a run whose section is an
    // *explicitly* zero-cost passthrough (degenerate trunks, zero
    // overhead, flush-every-commit, no crashes), must produce
    // bit-identical reports for every sync model.
    for kind in SyncModelKind::ALL {
        let spec = celled_spec(kind);
        let base = Run::from_spec(spec.clone()).backend(Backend::Sim).execute().unwrap();
        let mut degenerate = spec;
        degenerate.hierarchy = HierarchySpec {
            cells: vec![CellAggSpec::new("edge-a"), CellAggSpec::new("edge-b")],
            passthrough: true,
            ..HierarchySpec::default()
        };
        assert!(degenerate.hierarchy.is_zero_cost_passthrough());
        degenerate.validate().unwrap();
        let same = Run::from_spec(degenerate).backend(Backend::Sim).execute().unwrap();
        assert_reports_bit_identical(&base, &same, &format!("fog pin under {}", kind.name()));
    }
}

#[test]
fn hierarchical_runs_batch_commits_and_charge_edge_wait() {
    // A combining tier under a commit-heavy policy: fewer trunk flushes
    // than member arrivals, per-member commit accounting intact (one
    // applied commit per member contribution), and the buffering window
    // showing up in the EdgeWait attribution lane.
    use adsp::obs::{ObsConfig, ObsHub, TimeClass};
    let mut spec = celled_spec(SyncModelKind::Tap);
    spec.hierarchy = fog_section();
    spec.validate().unwrap();
    let hub = ObsHub::new(ObsConfig::metrics_only());
    let report = Run::from_spec(spec.clone()).observability(&hub).execute().unwrap();
    assert!(report.total_commits > 0, "hierarchical run never committed");
    assert!(report.final_loss.is_finite());
    assert!(report.best_loss < report.loss_log.first_loss().unwrap(), "training regressed");
    assert_eq!(report.wasted_steps, 0, "crash-free fog tier wasted work");
    let m = report.metrics.as_ref().expect("metrics missing");
    let arrivals = m.counter("hierarchy/member_arrivals");
    let flushes = m.counter("hierarchy/flushes");
    assert!(arrivals > 0, "no member commits reached an aggregator");
    assert!(flushes > 0, "aggregators never flushed");
    assert!(flushes < arrivals, "every-2 flush policy never batched: {flushes} of {arrivals}");
    assert!(m.counter("hierarchy/trunk_bytes_up") > 0, "trunk moved no bytes");
    let attr = report.attribution.as_ref().expect("attribution missing");
    assert!(
        attr.total[TimeClass::EdgeWait as usize] > 0.0,
        "edge buffering charged no EdgeWait time"
    );
    // Determinism of the whole tier.
    let again = Run::from_spec(spec).execute().unwrap();
    assert_reports_bit_identical(&report, &again, "hierarchical determinism");
}

#[test]
fn aggregator_crash_wastes_inflight_work_exactly_once() {
    // Crash `edge-a`'s aggregator while its buffer is guaranteed
    // non-empty (a flush threshold the run can never reach): the buffered
    // member work is wasted exactly once, the flat-path worker keeps the
    // run alive, and the whole script replays bit for bit.
    use adsp::obs::{ObsConfig, ObsHub};
    let mut spec = celled_spec(SyncModelKind::Tap);
    spec.convergence_window = 10_000; // run to the horizon
    spec.cluster.workers[2].cell = String::new(); // worker 2 stays flat
    spec.hierarchy = HierarchySpec {
        cells: vec![CellAggSpec::new("edge-a")],
        default_flush: Some(FlushPolicy::EveryK(100_000)),
        ..HierarchySpec::default()
    };
    spec.timeline = ClusterTimeline::new(vec![ClusterEvent::AggregatorCrash {
        t: 60.0,
        cell: "edge-a".into(),
        restart_after: 10.0,
    }]);
    spec.validate().unwrap();
    let hub = ObsHub::new(ObsConfig::metrics_only());
    let report = Run::from_spec(spec.clone()).observability(&hub).execute().unwrap();
    let m = report.metrics.as_ref().expect("metrics missing");
    assert_eq!(m.counter("hierarchy/agg_crashes"), 1);
    assert_eq!(m.counter("hierarchy/agg_restarts"), 1, "recovery never re-notified");
    let lost = m.counter("hierarchy/commits_lost_to_agg_crash");
    assert!(lost > 0, "crash found an empty buffer despite the unreachable threshold");
    assert!(report.wasted_steps > 0, "lost contributions wasted no steps");
    assert!(report.total_commits > 0, "the flat-path worker stopped committing");
    adsp::run::check_report_invariants(&spec, &report).unwrap();
    let again = Run::from_spec(spec).execute().unwrap();
    assert_eq!(report.wasted_steps, again.wasted_steps, "waste accounting not deterministic");
    assert_reports_bit_identical(&report, &again, "agg crash determinism");
}

#[test]
fn agg_down_members_stall_or_fall_back_per_spec() {
    // The two outage behaviours: Stall holds member commits at the edge
    // (EdgeWait grows, arrivals re-queue), Direct reroutes them onto the
    // flat path for the outage window.
    use adsp::obs::{ObsConfig, ObsHub};
    let run_mode = |mode: AggDownMode| {
        let mut spec = celled_spec(SyncModelKind::Tap);
        spec.convergence_window = 10_000;
        spec.hierarchy = fog_section();
        spec.hierarchy.on_agg_down = mode;
        spec.timeline = ClusterTimeline::new(vec![ClusterEvent::AggregatorCrash {
            t: 40.0,
            cell: "edge-a".into(),
            restart_after: 30.0,
        }]);
        spec.validate().unwrap();
        let hub = ObsHub::new(ObsConfig::metrics_only());
        let report = Run::from_spec(spec).observability(&hub).execute().unwrap();
        assert!(report.final_loss.is_finite(), "{mode:?} diverged");
        assert!(report.total_commits > 0, "{mode:?} stopped committing");
        report
    };
    let stalled = run_mode(AggDownMode::Stall);
    let m = stalled.metrics.as_ref().unwrap();
    assert!(
        m.counter("hierarchy/stalled_arrivals") > 0,
        "no member commit waited out the outage"
    );
    assert_eq!(m.counter("hierarchy/direct_fallbacks"), 0, "Stall leaked onto the flat path");
    let direct = run_mode(AggDownMode::Direct);
    let m = direct.metrics.as_ref().unwrap();
    assert!(
        m.counter("hierarchy/direct_fallbacks") > 0,
        "no member commit fell back to the flat path"
    );
    assert_eq!(m.counter("hierarchy/stalled_arrivals"), 0, "Direct stalled an arrival");
}

#[test]
fn realtime_engine_runs_hierarchical_cells() {
    // Wall-clock fog tier: relay threads buffer member commits, flush
    // them upstream over one emulated trunk transfer, and the run
    // completes with batched flushes visible in the hub.
    use adsp::obs::{ObsConfig, ObsHub};
    let mut spec = celled_spec(SyncModelKind::Adsp);
    spec.max_virtual_secs = 120.0;
    spec.max_total_steps = 1500;
    spec.eval_interval_secs = 10.0;
    spec.hierarchy = fog_section();
    spec.hierarchy.default_comm_secs = 0.1;
    spec.validate().unwrap();
    let hub = ObsHub::new(ObsConfig::metrics_only());
    let out = Run::from_spec(spec)
        .backend(Backend::Realtime { time_scale: 0.01 })
        .observability(&hub)
        .execute()
        .unwrap();
    assert!(out.total_steps > 0, "no steps trained");
    assert!(out.total_commits > 0, "no commits crossed the fog tier");
    assert!(out.final_loss.is_finite());
    let m = out.metrics.as_ref().expect("metrics missing");
    assert!(m.counter("hierarchy/flushes") > 0, "relays never flushed");
    assert!(
        m.counter("hierarchy/member_arrivals") >= m.counter("hierarchy/flushes"),
        "more flushes than member arrivals"
    );
    assert!(out.wall_secs < 30.0, "realtime fog run took too long: {}", out.wall_secs);
}

#[test]
fn realtime_relays_survive_aggregator_crash() {
    // A crash mid-run under both outage modes: the relay holds (Stall) or
    // flat-forwards (Direct) and the run always completes.
    for mode in [AggDownMode::Stall, AggDownMode::Direct] {
        let mut spec = celled_spec(SyncModelKind::Adsp);
        spec.max_virtual_secs = 120.0;
        spec.max_total_steps = 1500;
        spec.eval_interval_secs = 10.0;
        spec.hierarchy = fog_section();
        spec.hierarchy.default_comm_secs = 0.05;
        spec.hierarchy.on_agg_down = mode;
        spec.timeline = ClusterTimeline::new(vec![ClusterEvent::AggregatorCrash {
            t: 40.0,
            cell: "edge-a".into(),
            restart_after: 20.0,
        }]);
        spec.validate().unwrap();
        let out = Run::from_spec(spec)
            .backend(Backend::Realtime { time_scale: 0.01 })
            .execute()
            .unwrap();
        assert!(out.total_steps > 0, "{mode:?}: no steps trained");
        assert!(out.total_commits > 0, "{mode:?}: no commits survived the outage");
        assert!(out.final_loss.is_finite(), "{mode:?} diverged");
        assert!(out.wall_secs < 30.0, "{mode:?}: realtime crash run took too long");
    }
}
