//! The fuzzing acceptance suite: constraint-aware random timelines ×
//! every sync policy × both engines, checked by two oracles.
//!
//! 1. **Invariant oracle** — `check_report_invariants` validates every
//!    fuzzed report against what both engines guarantee (loss-log
//!    consistency, worker-metric sums, fault-counter gating, engine caps).
//! 2. **Differential oracle** — pairs of runs that must agree bit for bit:
//!    obs-on vs obs-off, tiny vs huge `worker_metrics_cap` (gates
//!    materialization, not numbers), cohort spec vs its explicit
//!    expansion, and `shards = S` vs `shards = 1` on the communication-free
//!    variant (the simulator's only shard-dependent timings are comm legs).
//!
//! Every case is seed-addressed. On failure the panic message carries the
//! seed, and when `ADSP_FUZZ_DUMP_DIR` is set the failing spec is written
//! there as replayable JSON (`adsp train --config <dump>.json`). CI's fuzz
//! job widens the seed set via `ADSP_FUZZ_SEEDS` (comma-separated) and
//! pins the regime via `ADSP_FUZZ_INTENSITY` (light|heavy).

use adsp::cluster::{random_fleet_spec, zero_comm_variant, FuzzConfig, FuzzIntensity};
use adsp::config::ExperimentSpec;
use adsp::obs::{ObsConfig, ObsHub};
use adsp::run::{check_report_invariants, Backend, Run, RunReport};
use adsp::sync::SyncModelKind;
use adsp::util::Rng;

/// Seeds under test: `ADSP_FUZZ_SEEDS="3,17,99"` or the tier-1 default.
fn fuzz_seeds() -> Vec<u64> {
    std::env::var("ADSP_FUZZ_SEEDS")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect::<Vec<u64>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2])
}

/// Intensities under test: both by default, one when CI pins it.
fn fuzz_intensities() -> Vec<FuzzIntensity> {
    match std::env::var("ADSP_FUZZ_INTENSITY") {
        Ok(s) => vec![s.parse().expect("bad ADSP_FUZZ_INTENSITY")],
        Err(_) => vec![FuzzIntensity::Light, FuzzIntensity::Heavy],
    }
}

/// Write the failing spec where CI can pick it up as an artifact.
fn dump_spec(spec: &ExperimentSpec, tag: &str) {
    if let Ok(dir) = std::env::var("ADSP_FUZZ_DUMP_DIR") {
        let _ = std::fs::create_dir_all(&dir);
        let path = std::path::Path::new(&dir).join(format!("{tag}.json"));
        match spec.save(&path) {
            Ok(()) => eprintln!(
                "fuzz failure spec dumped to {} (replay: adsp train --config {})",
                path.display(),
                path.display()
            ),
            Err(e) => eprintln!("failed to dump fuzz spec for {tag}: {e}"),
        }
    }
}

/// Run `f`; if it panics, dump the spec for replay, then re-panic.
fn with_dump<T>(spec: &ExperimentSpec, tag: &str, f: impl FnOnce() -> T) -> T {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(v) => v,
        Err(e) => {
            dump_spec(spec, tag);
            std::panic::resume_unwind(e);
        }
    }
}

fn sim_run(spec: &ExperimentSpec, tag: &str) -> RunReport {
    match Run::from_spec(spec.clone()).execute() {
        Ok(r) => r,
        Err(e) => {
            dump_spec(spec, tag);
            panic!("{tag}: fuzzed sim run failed: {e}");
        }
    }
}

fn oracle_check(spec: &ExperimentSpec, report: &RunReport, tag: &str) {
    if let Err(e) = check_report_invariants(spec, report) {
        dump_spec(spec, tag);
        panic!("{tag}: invariant oracle failed: {e}");
    }
}

/// Bit-level equality of everything the simulator computes (same pin as
/// `tests/integration.rs`; test binaries cannot share helpers). Skips
/// `metrics`/`engine`, which is what makes it usable for the obs on/off
/// differential.
fn assert_reports_bit_identical(a: &RunReport, b: &RunReport, tag: &str) {
    assert_eq!(a.total_steps, b.total_steps, "{tag}: steps diverged");
    assert_eq!(a.total_commits, b.total_commits, "{tag}: commits diverged");
    assert_eq!(a.bytes_total, b.bytes_total, "{tag}: bytes diverged");
    assert_eq!(a.end_time.to_bits(), b.end_time.to_bits(), "{tag}: end time diverged");
    assert_eq!(
        a.converged_at.map(f64::to_bits),
        b.converged_at.map(f64::to_bits),
        "{tag}: convergence time diverged"
    );
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{tag}: final loss");
    assert_eq!(a.best_loss.to_bits(), b.best_loss.to_bits(), "{tag}: best loss");
    assert_eq!(
        a.final_accuracy.to_bits(),
        b.final_accuracy.to_bits(),
        "{tag}: final accuracy"
    );
    assert_eq!(a.wasted_steps, b.wasted_steps, "{tag}: wasted steps");
    assert_eq!(a.lost_commits, b.lost_commits, "{tag}: lost commits");
    assert_eq!(a.checkpoints_taken, b.checkpoints_taken, "{tag}: checkpoints");
    assert_eq!(
        a.checkpoint_overhead_secs.to_bits(),
        b.checkpoint_overhead_secs.to_bits(),
        "{tag}: checkpoint overhead"
    );
    assert_eq!(a.loss_log.samples.len(), b.loss_log.samples.len(), "{tag}: eval count");
    for (x, y) in a.loss_log.samples.iter().zip(&b.loss_log.samples) {
        assert_eq!(x.t.to_bits(), y.t.to_bits(), "{tag}: eval time diverged");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{tag}: loss log diverged");
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{tag}: accuracy log");
        assert_eq!(x.total_steps, y.total_steps, "{tag}: step log diverged");
    }
    assert_eq!(a.workers.len(), b.workers.len(), "{tag}: worker count");
    for (x, y) in a.workers.iter().zip(&b.workers) {
        assert_eq!(x.steps, y.steps, "{tag}: worker steps");
        assert_eq!(x.commits, y.commits, "{tag}: worker commits");
        assert_eq!(x.bytes_up, y.bytes_up, "{tag}: worker bytes up");
        assert_eq!(x.bytes_down, y.bytes_down, "{tag}: worker bytes down");
        assert_eq!(x.compute_secs.to_bits(), y.compute_secs.to_bits(), "{tag}: compute");
        assert_eq!(x.comm_secs.to_bits(), y.comm_secs.to_bits(), "{tag}: comm");
        assert_eq!(x.blocked_secs.to_bits(), y.blocked_secs.to_bits(), "{tag}: blocked");
    }
    assert_eq!(a.sync, b.sync, "{tag}: sync kind");
    assert_eq!(a.sync_describe, b.sync_describe, "{tag}: sync describe");
}

/// The same equality with the per-worker block replaced by a
/// materialization check — the `worker_metrics_cap` differential: the cap
/// gates whether per-worker metrics are *kept*, never what is *computed*.
fn assert_reports_identical_except_workers(
    a: &RunReport,
    b: &RunReport,
    want_workers_a: usize,
    want_workers_b: usize,
    tag: &str,
) {
    let mut a2 = a.clone();
    let mut b2 = b.clone();
    assert_eq!(a2.workers.len(), want_workers_a, "{tag}: materialization gate (a)");
    assert_eq!(b2.workers.len(), want_workers_b, "{tag}: materialization gate (b)");
    a2.workers.clear();
    b2.workers.clear();
    assert_reports_bit_identical(&a2, &b2, tag);
}

// ---------------------------------------------------------------------------
// Generator properties
// ---------------------------------------------------------------------------

#[test]
fn prop_fuzzed_timelines_always_validate() {
    // 300 random fleet shapes × event mixes × intensities: every generated
    // timeline must pass validate_full against its own config — the
    // correct-by-construction acceptance bound.
    let mut rng = Rng::new(0xF0_22_300);
    for case in 0..300u64 {
        let mut r = rng.split(case);
        let workers = 1 + r.below(12);
        let mut cfg = FuzzConfig::new(workers, 1 + r.below(5), 10.0 + 990.0 * r.next_f64());
        if r.below(2) == 0 {
            let labels = ["", "cell-a", "cell-b", "cell-c"];
            cfg.cells = (0..workers).map(|_| labels[r.below(labels.len())].to_string()).collect();
            if cfg.cells.iter().all(|c| c.is_empty()) {
                cfg.cells = Vec::new();
            }
        }
        if r.below(2) == 0 {
            cfg.intensity = FuzzIntensity::Heavy;
        }
        // Random weights, zeros included (a zero disables that kind).
        cfg.event_mix.speed = r.below(6) as u32;
        cfg.event_mix.comm = r.below(6) as u32;
        cfg.event_mix.bandwidth = r.below(6) as u32;
        cfg.event_mix.blackout = r.below(6) as u32;
        cfg.event_mix.join = r.below(6) as u32;
        cfg.event_mix.leave = r.below(6) as u32;
        cfg.event_mix.crash = r.below(6) as u32;
        cfg.event_mix.shard = r.below(6) as u32;
        cfg.event_mix.agg_crash = r.below(6) as u32;
        let seed = r.next_u64();
        let tl = cfg.generate(seed);
        assert!(!tl.is_empty(), "case {case} seed {seed}: empty timeline for a live fleet");
        tl.validate_full(cfg.workers, cfg.shards, &cfg.cells).unwrap_or_else(|e| {
            panic!(
                "case {case} seed {seed} (workers={} shards={} horizon={:.1}): {e}",
                cfg.workers, cfg.shards, cfg.horizon
            )
        });
        // Seed addressing: the same (config, seed) pair regenerates the
        // identical timeline.
        assert_eq!(cfg.generate(seed), tl, "case {case} seed {seed}: not deterministic");
    }
}

#[test]
fn fuzzed_fleet_specs_are_deterministic_per_seed() {
    for intensity in [FuzzIntensity::Light, FuzzIntensity::Heavy] {
        for seed in [0u64, 7, 0xDEAD_BEEF] {
            let a = random_fleet_spec(seed, SyncModelKind::Adsp, intensity);
            let b = random_fleet_spec(seed, SyncModelKind::Adsp, intensity);
            assert_eq!(
                a.to_json().dump(),
                b.to_json().dump(),
                "seed {seed} {}: spec generation not deterministic",
                intensity.name()
            );
            a.validate().unwrap_or_else(|e| {
                panic!("seed {seed} {}: invalid fuzzed spec: {e}", intensity.name())
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Oracle 1: invariants + bit-identical replays, all policies
// ---------------------------------------------------------------------------

#[test]
fn fuzzed_sim_runs_are_deterministic_and_pass_the_invariant_oracle() {
    for intensity in fuzz_intensities() {
        for seed in fuzz_seeds() {
            for kind in SyncModelKind::ALL {
                let tag = format!("sim-{}-seed{seed}-{}", kind.name(), intensity.name());
                let spec = random_fleet_spec(seed, kind, intensity);
                let first = sim_run(&spec, &tag);
                let again = sim_run(&spec, &tag);
                with_dump(&spec, &tag, || {
                    assert_reports_bit_identical(&first, &again, &tag);
                });
                oracle_check(&spec, &first, &tag);
            }
        }
    }
}

#[test]
fn fuzzed_realtime_runs_pass_the_invariant_oracle() {
    // The wall-clock engine is nondeterministic, so no bit pins here —
    // the invariant oracle (with its realtime-lenient caps) is the check.
    let seed = fuzz_seeds()[0];
    for kind in [SyncModelKind::Adsp, SyncModelKind::Bsp, SyncModelKind::Ssp] {
        let tag = format!("realtime-{}-seed{seed}", kind.name());
        let spec = random_fleet_spec(seed, kind, FuzzIntensity::Light);
        let report = match Run::from_spec(spec.clone())
            .backend(Backend::Realtime { time_scale: 0.002 })
            .execute()
        {
            Ok(r) => r,
            Err(e) => {
                dump_spec(&spec, &tag);
                panic!("{tag}: fuzzed realtime run failed: {e}");
            }
        };
        oracle_check(&spec, &report, &tag);
    }
}

// ---------------------------------------------------------------------------
// Oracle 2: differential re-runs, all policies
// ---------------------------------------------------------------------------

#[test]
fn fuzzed_obs_on_equals_obs_off_bitwise() {
    for seed in fuzz_seeds() {
        for kind in SyncModelKind::ALL {
            let tag = format!("obs-{}-seed{seed}", kind.name());
            let spec = random_fleet_spec(seed, kind, FuzzIntensity::Light);
            let plain = sim_run(&spec, &tag);
            let hub = ObsHub::new(ObsConfig::metrics_only());
            let observed = match Run::from_spec(spec.clone()).observability(&hub).execute() {
                Ok(r) => r,
                Err(e) => {
                    dump_spec(&spec, &tag);
                    panic!("{tag}: obs-on run failed: {e}");
                }
            };
            with_dump(&spec, &tag, || {
                assert_reports_bit_identical(&plain, &observed, &tag);
                assert!(plain.metrics.is_none(), "{tag}: phantom metrics without a hub");
                assert!(observed.metrics.is_some(), "{tag}: hub produced no metrics");
            });
            oracle_check(&spec, &observed, &tag);
        }
    }
}

#[test]
fn fuzzed_worker_metrics_cap_gates_materialization_not_bits() {
    for seed in fuzz_seeds() {
        for kind in SyncModelKind::ALL {
            let tag = format!("cap-{}-seed{seed}", kind.name());
            let spec = random_fleet_spec(seed, kind, FuzzIntensity::Light);
            let m_final = spec
                .expanded()
                .expect("expansion")
                .map(|e| e.cluster.m())
                .unwrap_or_else(|| spec.cluster.m())
                + spec.timeline.join_count();
            let mut capped = spec.clone();
            capped.worker_metrics_cap = 0;
            let full = sim_run(&spec, &tag);
            let gated = sim_run(&capped, &tag);
            with_dump(&spec, &tag, || {
                assert_reports_identical_except_workers(&full, &gated, m_final, 0, &tag);
            });
            oracle_check(&capped, &gated, &tag);
        }
    }
}

#[test]
fn fuzzed_cohort_spec_equals_its_explicit_expansion() {
    // Cohort sugar is spec-level only: running the unexpanded spec and its
    // pre-expanded explicit-worker form must agree bit for bit.
    for seed in fuzz_seeds() {
        for kind in SyncModelKind::ALL {
            let tag = format!("cohort-{}-seed{seed}", kind.name());
            let spec = random_fleet_spec(seed, kind, FuzzIntensity::Light);
            let explicit = spec
                .expanded()
                .expect("expansion")
                .expect("fuzzed fleet specs always carry a cohort");
            let a = sim_run(&spec, &tag);
            let b = sim_run(&explicit, &tag);
            with_dump(&spec, &tag, || {
                assert_reports_bit_identical(&a, &b, &tag);
            });
        }
    }
}

#[test]
fn fuzzed_flat_equals_zero_cost_passthrough_hierarchy_bitwise() {
    // The fog tier's structural pin: a passthrough hierarchy whose
    // aggregators add zero cost (degenerate trunks, zero overhead,
    // flush-every-commit) and never crash *is* the flat topology — the
    // engines elide the tier, so the pair must agree bit for bit under
    // every policy, on fuzzed fleets and timelines.
    use adsp::cluster::{ClusterEvent, ClusterTimeline};
    use adsp::hierarchy::{CellAggSpec, HierarchySpec};
    for seed in fuzz_seeds() {
        for kind in SyncModelKind::ALL {
            let tag = format!("hier-{}-seed{seed}", kind.name());
            let mut flat = random_fleet_spec(seed, kind, FuzzIntensity::Light);
            // Normalize the pair under test: no fuzzed fog tier, no
            // aggregator crashes (a crashed zero-cost tier is *not*
            // degenerate and legitimately diverges).
            flat.hierarchy = HierarchySpec::default();
            let events: Vec<ClusterEvent> = flat
                .timeline
                .events()
                .iter()
                .filter(|e| !matches!(e, ClusterEvent::AggregatorCrash { .. }))
                .cloned()
                .collect();
            flat.timeline = ClusterTimeline::new(events);
            // Aggregate every labelled cell of the expanded fleet.
            let labels = {
                let mut seen: Vec<String> = Vec::new();
                for c in FuzzConfig::for_spec(&flat, FuzzIntensity::Light).cells {
                    if !c.is_empty() && !seen.contains(&c) {
                        seen.push(c);
                    }
                }
                seen
            };
            if labels.is_empty() {
                continue; // unlabelled fleet: nothing to aggregate
            }
            let mut hier = flat.clone();
            hier.hierarchy = HierarchySpec {
                cells: labels.iter().map(|l| CellAggSpec::new(l)).collect(),
                passthrough: true,
                ..HierarchySpec::default()
            };
            assert!(hier.hierarchy.is_zero_cost_passthrough(), "{tag}: pin setup");
            hier.validate().unwrap_or_else(|e| panic!("{tag}: {e}"));
            let a = sim_run(&flat, &tag);
            let b = sim_run(&hier, &tag);
            with_dump(&hier, &tag, || {
                assert_reports_bit_identical(&a, &b, &tag);
            });
        }
    }
}

#[test]
fn fuzzed_shard_count_is_bit_invariant_without_communication() {
    // The simulator's only shard-dependent timings are the comm one-way leg
    // and the PS apply service time; the zero-comm variant removes both, so
    // S shards must replay the S = 1 run exactly — for every policy, on
    // fuzzed timelines that keep churn, blackouts, crashes, bandwidth
    // changes and shard-0 failures.
    for seed in fuzz_seeds() {
        for kind in SyncModelKind::ALL {
            let tag = format!("shards-{}-seed{seed}", kind.name());
            let base = zero_comm_variant(&random_fleet_spec(seed, kind, FuzzIntensity::Heavy));
            let mut single = base.clone();
            single.shards = 1;
            let a = sim_run(&base, &tag);
            let b = sim_run(&single, &tag);
            with_dump(&base, &tag, || {
                assert_reports_bit_identical(
                    &a,
                    &b,
                    &format!("{tag} (S={} vs S=1)", base.shards),
                );
            });
        }
    }
}
