//! Property-based tests over the coordinator invariants (hand-rolled
//! randomized harness — proptest is unavailable offline; the structure is
//! the same: generate hundreds of random scenarios with a seeded RNG and
//! assert invariants on every one, printing the failing seed on panic).
//!
//! The mock engine here mirrors `simulation::engine` event semantics but
//! replaces the XLA step with a counter bump, so thousands of policy
//! decisions run per millisecond and the *policy* invariants get exercised
//! far beyond what the full-stack tests can afford:
//!
//! * BSP — lockstep: commit counts never differ by more than 1.
//! * SSP(s) — staleness: `steps_i − min_j steps_j ≤ s + k_chunk` always.
//! * TAP / ADSP / ADSP⁺ — never block.
//! * (Fixed) ADACOMM — commits happen exactly every τ local steps.
//! * ADSP — commit counts stay ε-balanced at checkpoints (Theorem 2's
//!   precondition) and ΔC assignments favor laggards.
//! * Curve fit — recovers planted (a1, a2, a3) under noise.

use adsp::config::{ClusterSpec, SyncSpec, WorkerSpec};
use adsp::sync::{
    implicit_momentum, make_policy, Action, ClusterView, SyncModelKind, SyncPolicy,
    WorkerProgress, WorkerSlabs,
};
use adsp::util::{fit_inverse_curve, Json, Rng};

const K_VARIANTS: [usize; 3] = [16, 4, 1];

/// Policy-only discrete-event mock of the simulator (no XLA, no data).
struct MockEngine {
    policy: Box<dyn SyncPolicy>,
    progress: WorkerSlabs,
    speeds: Vec<f64>,
    comms: Vec<f64>,
    gamma: f64,
    now: f64,
    queue: std::collections::BinaryHeap<std::cmp::Reverse<(u64, u64, usize, u8)>>,
    seq: u64,
    next_checkpoint: f64,
    /// Synthetic decaying loss fed to on_eval.
    next_eval: f64,
    /// Records (worker, steps_at_commit_initiation, local_since_commit) rows.
    commit_trace: Vec<(usize, u64, u64)>,
    max_staleness_seen: u64,
    blocked_ever: bool,
    /// When set, at this time the current laggard leaves and a fresh
    /// worker joins (bootstrapped to the active minimum), mirroring the
    /// engines' timeline-churn handling.
    churn_at: Option<f64>,
    join_speed: f64,
    joined_at_steps: Option<u64>,
}

const EV_READY: u8 = 0;
const EV_ARRIVE: u8 = 1;

fn key(t: f64) -> u64 {
    // Microsecond-resolution ordering key (monotone in t for t >= 0).
    (t * 1e6) as u64
}

impl MockEngine {
    fn new(kind: SyncModelKind, cluster: &ClusterSpec, sync: &SyncSpec) -> Self {
        let m = cluster.m();
        let mut spec = sync.clone();
        spec.kind = kind;
        let mut progress = WorkerSlabs::new();
        for _ in 0..m {
            progress.push(WorkerProgress { batch_size: 32, ..Default::default() });
        }
        MockEngine {
            policy: make_policy(&spec, cluster),
            progress,
            speeds: cluster.speeds(),
            comms: cluster.comms(),
            gamma: sync.gamma,
            now: 0.0,
            queue: std::collections::BinaryHeap::new(),
            seq: 0,
            next_checkpoint: sync.gamma,
            next_eval: 0.0,
            commit_trace: Vec::new(),
            max_staleness_seen: 0,
            blocked_ever: false,
            churn_at: None,
            join_speed: 1.0,
            joined_at_steps: None,
        }
    }

    #[allow(dead_code)]
    fn view(&self) -> ClusterView<'_> {
        ClusterView {
            now: self.now,
            workers: &self.progress,
            speeds: &self.speeds,
            comms: &self.comms,
            k_variants: &K_VARIANTS,
            last_eval: None,
            initial_loss: Some(2.0),
        }
    }

    fn push(&mut self, t: f64, w: usize, ev: u8) {
        self.seq += 1;
        self.queue.push(std::cmp::Reverse((key(t), self.seq, w, ev)));
    }

    fn drive(&mut self, w: usize) {
        if !self.progress.is_active(w) {
            return; // stale event for a departed worker
        }
        let action = {
            let view = ClusterView {
                now: self.now,
                workers: &self.progress,
                speeds: &self.speeds,
                comms: &self.comms,
                k_variants: &K_VARIANTS,
                last_eval: None,
                initial_loss: Some(2.0),
            };
            self.policy.next_action(w, &view)
        };
        match action {
            Action::Train { k } => {
                let k = k.max(1);
                self.progress.bump_steps(w, k);
                self.progress.local_since_commit[w] += k;
                let all_min = (0..self.progress.len())
                    .map(|i| self.progress.steps(i))
                    .min()
                    .unwrap();
                let stale = self.progress.steps(w) - all_min;
                self.max_staleness_seen = self.max_staleness_seen.max(stale);
                let dt = k as f64 / self.speeds[w];
                let t = self.now + dt;
                self.push(t, w, EV_READY);
            }
            Action::Commit => {
                self.commit_trace.push((
                    w,
                    self.progress.steps(w),
                    self.progress.local_since_commit[w],
                ));
                self.progress.local_since_commit[w] = 0;
                self.push(self.now + self.comms[w] / 2.0, w, EV_ARRIVE);
            }
            Action::Block => {
                self.progress.set_blocked(w, true);
                self.blocked_ever = true;
            }
        }
    }

    /// Retire the current laggard and join a replacement at the active
    /// minimum — the mock analogue of the engines' churn handling.
    fn do_churn(&mut self) {
        let laggard = (0..self.progress.len())
            .filter(|&i| self.progress.is_active(i))
            .min_by_key(|&i| self.progress.steps(i))
            .expect("active worker");
        if self.progress.active_count() > 1 {
            // Blocked is a sub-state of active: clear it first.
            self.progress.set_blocked(laggard, false);
            self.progress.set_active(laggard, false);
        }
        let (min_steps, min_commits) = (self.progress.min_steps(), self.progress.min_commits());
        let j = self.progress.len();
        self.progress.push(WorkerProgress {
            steps: min_steps,
            commits: min_commits,
            batch_size: 32,
            ..Default::default()
        });
        self.joined_at_steps = Some(min_steps);
        self.speeds.push(self.join_speed);
        self.comms.push(0.2);
        let view = ClusterView {
            now: self.now,
            workers: &self.progress,
            speeds: &self.speeds,
            comms: &self.comms,
            k_variants: &K_VARIANTS,
            last_eval: None,
            initial_loss: Some(2.0),
        };
        self.policy.on_cluster_change(&view);
        self.push(self.now, j, EV_READY);
    }

    /// Run until `horizon`; returns false on policy deadlock.
    fn run(&mut self, horizon: f64, mut on_commit: impl FnMut(&Self, usize)) -> bool {
        for w in 0..self.progress.len() {
            self.push(0.0, w, EV_READY);
        }
        while let Some(std::cmp::Reverse((tk, _, w, ev))) = self.queue.pop() {
            self.now = tk as f64 / 1e6;
            if self.now > horizon {
                return true;
            }
            if let Some(tc) = self.churn_at {
                if self.now >= tc {
                    self.churn_at = None;
                    self.do_churn();
                }
            }
            while self.next_eval <= self.now {
                // Synthetic 1/t loss curve.
                let loss = 2.0 / (1.0 + 0.01 * self.next_eval) + 0.1;
                self.policy.on_eval(self.next_eval, loss);
                self.next_eval += 5.0;
            }
            while self.next_checkpoint <= self.now {
                let view = ClusterView {
                    now: self.next_checkpoint,
                    workers: &self.progress,
                    speeds: &self.speeds,
                    comms: &self.comms,
                    k_variants: &K_VARIANTS,
                    last_eval: None,
                    initial_loss: Some(2.0),
                };
                self.policy.on_checkpoint(&view);
                self.next_checkpoint += self.gamma;
            }
            match ev {
                EV_READY => self.drive(w),
                EV_ARRIVE if !self.progress.is_active(w) => {} // commit lost with the leaver
                EV_ARRIVE => {
                    self.progress.bump_commits(w);
                    let view = ClusterView {
                        now: self.now,
                        workers: &self.progress,
                        speeds: &self.speeds,
                        comms: &self.comms,
                        k_variants: &K_VARIANTS,
                        last_eval: None,
                        initial_loss: Some(2.0),
                    };
                    self.policy.on_commit_applied(w, &view);
                    on_commit(self, w);
                    self.push(self.now + self.comms[w] / 2.0, w, EV_READY);
                }
                _ => unreachable!(),
            }
            // Re-poll blocked workers.
            let blocked: Vec<usize> =
                (0..self.progress.len()).filter(|&i| self.progress.is_blocked(i)).collect();
            for i in blocked {
                let action = {
                    let view = ClusterView {
                        now: self.now,
                        workers: &self.progress,
                        speeds: &self.speeds,
                        comms: &self.comms,
                        k_variants: &K_VARIANTS,
                        last_eval: None,
                        initial_loss: Some(2.0),
                    };
                    self.policy.next_action(i, &view)
                };
                if action != Action::Block {
                    self.progress.set_blocked(i, false);
                    self.push(self.now, i, EV_READY);
                }
            }
            // Blocked is a sub-state of active, so "every active worker is
            // blocked" is an O(1) counter comparison on the slabs.
            let active_all_blocked = self.progress.active_count() > 0
                && self.progress.blocked_count() == self.progress.active_count();
            if self.queue.is_empty() && active_all_blocked {
                return false; // deadlock
            }
        }
        true
    }
}

fn random_cluster(rng: &mut Rng) -> ClusterSpec {
    let m = 2 + rng.below(6);
    ClusterSpec::new(
        (0..m)
            .map(|_| {
                WorkerSpec::new(0.3 + 3.0 * rng.next_f64(), 0.05 + 0.4 * rng.next_f64())
            })
            .collect(),
    )
}

fn random_sync(rng: &mut Rng, kind: SyncModelKind) -> SyncSpec {
    let mut s = SyncSpec::new(kind);
    s.gamma = 10.0 + 40.0 * rng.next_f64();
    s.epoch_secs = 1000.0;
    s.eval_window_secs = 15.0;
    s.tau = 1 + rng.below(12) as u64;
    s.staleness = 1 + rng.below(6) as u64;
    s
}

const CASES: usize = 150;

#[test]
fn prop_bsp_lockstep() {
    let mut rng = Rng::new(0xB5B);
    for case in 0..CASES {
        let mut case_rng = rng.split(case as u64);
        let cluster = random_cluster(&mut case_rng);
        let sync = random_sync(&mut case_rng, SyncModelKind::Bsp);
        let mut eng = MockEngine::new(SyncModelKind::Bsp, &cluster, &sync);
        let ok = eng.run(300.0, |e, _| {
            let min = (0..e.progress.len()).map(|i| e.progress.commits(i)).min().unwrap();
            let max = (0..e.progress.len()).map(|i| e.progress.commits(i)).max().unwrap();
            assert!(max - min <= 1, "case {case}: BSP lockstep broken: {min}..{max}");
        });
        assert!(ok, "case {case}: BSP deadlocked");
        // BSP commits exactly once per local step.
        for &(_, _, local) in &eng.commit_trace {
            assert_eq!(local, 1, "case {case}: BSP must commit every step");
        }
    }
}

#[test]
fn prop_ssp_staleness_bound() {
    let mut rng = Rng::new(0x55B);
    for case in 0..CASES {
        let mut case_rng = rng.split(case as u64);
        let cluster = random_cluster(&mut case_rng);
        let sync = random_sync(&mut case_rng, SyncModelKind::Ssp);
        let s = sync.staleness;
        let mut eng = MockEngine::new(SyncModelKind::Ssp, &cluster, &sync);
        let ok = eng.run(300.0, |_, _| {});
        assert!(ok, "case {case}: SSP deadlocked");
        // SSP trains k=1 chunks, so the bound is exactly s (the mock counts
        // steps at chunk start, adding at most one in-flight step).
        assert!(
            eng.max_staleness_seen <= s + 1,
            "case {case}: staleness {} exceeded bound {}",
            eng.max_staleness_seen,
            s
        );
    }
}

#[test]
fn prop_never_blocking_policies_never_block() {
    let mut rng = Rng::new(0x7A9);
    for kind in [SyncModelKind::Tap, SyncModelKind::Adsp, SyncModelKind::AdspPlus] {
        for case in 0..CASES / 3 {
            let mut case_rng = rng.split(case as u64);
            let cluster = random_cluster(&mut case_rng);
            let sync = random_sync(&mut case_rng, kind);
            let mut eng = MockEngine::new(kind, &cluster, &sync);
            let ok = eng.run(300.0, |_, _| {});
            assert!(ok, "case {case}: {kind} deadlocked");
            assert!(!eng.blocked_ever, "case {case}: {kind} blocked a worker");
        }
    }
}

#[test]
fn prop_fixed_adacomm_commits_every_tau() {
    let mut rng = Rng::new(0xADA);
    for case in 0..CASES {
        let mut case_rng = rng.split(case as u64);
        let cluster = random_cluster(&mut case_rng);
        let sync = random_sync(&mut case_rng, SyncModelKind::FixedAdacomm);
        let tau = sync.tau;
        let mut eng = MockEngine::new(SyncModelKind::FixedAdacomm, &cluster, &sync);
        let ok = eng.run(300.0, |_, _| {});
        assert!(ok, "case {case}: FixedAdacomm deadlocked");
        assert!(!eng.commit_trace.is_empty());
        for &(w, _, local) in &eng.commit_trace {
            assert_eq!(local, tau, "case {case}: worker {w} committed off-τ ({local} vs {tau})");
        }
    }
}

#[test]
fn prop_adsp_commit_balance_at_horizon() {
    let mut rng = Rng::new(0xAD5);
    for case in 0..CASES {
        let mut case_rng = rng.split(case as u64);
        let cluster = random_cluster(&mut case_rng);
        let sync = random_sync(&mut case_rng, SyncModelKind::Adsp);
        let mut eng = MockEngine::new(SyncModelKind::Adsp, &cluster, &sync);
        let ok = eng.run(400.0, |_, _| {});
        assert!(ok, "case {case}: ADSP deadlocked");
        let commits: Vec<u64> =
            (0..eng.progress.len()).map(|i| eng.progress.commits(i)).collect();
        let min = *commits.iter().min().unwrap();
        let max = *commits.iter().max().unwrap();
        assert!(
            max.saturating_sub(min) <= 4,
            "case {case}: ADSP commit imbalance {commits:?} (H={:.2})",
            cluster.heterogeneity()
        );
    }
}

#[test]
fn prop_adsp_assigns_larger_rates_to_laggards() {
    let mut rng = Rng::new(0xDC1);
    for case in 0..CASES {
        let mut case_rng = rng.split(case as u64);
        let cluster = random_cluster(&mut case_rng);
        let m = cluster.m();
        let sync = random_sync(&mut case_rng, SyncModelKind::Adsp);
        let mut policy = make_policy(&sync, &cluster);
        // Synthesize unequal commit counts and fire a checkpoint.
        let mut workers = WorkerSlabs::new();
        for i in 0..m {
            workers.push(WorkerProgress {
                batch_size: 32,
                commits: (i as u64) * 2,
                ..Default::default()
            });
        }
        let view = ClusterView {
            now: sync.gamma,
            workers: &workers,
            speeds: &cluster.speeds(),
            comms: &cluster.comms(),
            k_variants: &K_VARIANTS,
            last_eval: None,
            initial_loss: Some(2.0),
        };
        policy.on_checkpoint(&view);
        let dc: Vec<f64> = (0..m).map(|w| policy.delta_c(w).unwrap()).collect();
        for i in 1..m {
            assert!(
                dc[i - 1] >= dc[i] - 1e-9,
                "case {case}: laggard {} got smaller ΔC than leader {}: {dc:?}",
                i - 1,
                i
            );
        }
    }
}

#[test]
fn prop_implicit_momentum_bounds_and_monotonicity() {
    let mut rng = Rng::new(0x313);
    for case in 0..CASES {
        let mut r = rng.split(case as u64);
        let m = 2 + r.below(8);
        let gamma = 10.0 + 100.0 * r.next_f64();
        let speeds: Vec<f64> = (0..m).map(|_| 0.1 + 3.0 * r.next_f64()).collect();
        let dc1: Vec<f64> = (0..m).map(|_| 1.0 + 10.0 * r.next_f64()).collect();
        let dc2: Vec<f64> = dc1.iter().map(|d| d * 2.0).collect();
        let mu1 = implicit_momentum(gamma, &dc1, &speeds);
        let mu2 = implicit_momentum(gamma, &dc2, &speeds);
        assert!((0.0..1.0).contains(&mu1), "case {case}: mu out of range: {mu1}");
        assert!(mu2 < mu1, "case {case}: doubling rates must reduce momentum");
    }
}

#[test]
fn prop_fit_recovers_planted_curves() {
    let mut rng = Rng::new(0xF17);
    for case in 0..60 {
        let mut r = rng.split(case as u64);
        let a1 = 0.05 + 0.5 * r.next_f64();
        let a2 = 0.2 + 2.0 * r.next_f64();
        let a3 = r.next_f64();
        let samples: Vec<(f64, f64)> = (0..40)
            .map(|i| {
                let t = 1.0 + i as f64 * 3.0;
                (t, 1.0 / (a1 * a1 * t + a2) + a3 + 0.001 * r.normal())
            })
            .collect();
        let fit = fit_inverse_curve(&samples).expect("fit failed");
        // Prediction error at held-out points stays small.
        for &t in &[5.5, 60.5, 110.5] {
            let truth = 1.0 / (a1 * a1 * t + a2) + a3;
            assert!(
                (fit.predict(t) - truth).abs() < 0.05,
                "case {case}: fit off at t={t}: {} vs {truth}",
                fit.predict(t)
            );
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    let mut rng = Rng::new(0x15);
    fn random_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.next_f64() < 0.5),
            2 => Json::Num((r.next_f64() * 2000.0 - 1000.0 * 64.0).round() / 64.0),
            3 => {
                let n = r.below(12);
                Json::Str((0..n).map(|_| char::from(32 + r.below(94) as u8)).collect())
            }
            4 => Json::Arr((0..r.below(5)).map(|_| random_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(5))
                    .map(|i| (format!("k{i}"), random_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    for case in 0..300 {
        let mut r = rng.split(case);
        let v = random_json(&mut r, 3);
        let text = v.dump();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case} roundtrip failed: {text}");
        let back2 = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(back2, v);
    }
}

#[test]
fn prop_batchtune_keeps_global_batch() {
    let mut rng = Rng::new(0xBA7);
    let available = [32usize, 64, 128, 256];
    for case in 0..CASES {
        let mut r = rng.split(case as u64);
        let m = 2 + r.below(10);
        let speeds: Vec<f64> = (0..m).map(|_| 0.4 + 3.0 * r.next_f64()).collect();
        let sizes = adsp::sync::assign_batchtune_sizes(&speeds, 128, &available);
        assert_eq!(sizes.len(), m);
        // Faster workers never get smaller batches than slower ones.
        let mut idx: Vec<usize> = (0..m).collect();
        idx.sort_by(|&a, &b| speeds[a].total_cmp(&speeds[b]));
        for pair in idx.windows(2) {
            assert!(
                sizes[pair[0]] <= sizes[pair[1]],
                "case {case}: batch ordering broken: speeds={speeds:?} sizes={sizes:?}"
            );
        }
        // Global batch within 40% of m*128 (rounding to available sizes).
        let total: usize = sizes.iter().sum();
        let want = m * 128;
        assert!(
            (total as f64 - want as f64).abs() / want as f64 <= 0.4,
            "case {case}: global batch drifted: {total} vs {want}"
        );
    }
}

// ---------------------------------------------------------------------------
// Sharded parameter server (pserver) invariants
// ---------------------------------------------------------------------------
//
// Same hand-rolled randomized structure as above, shaped like a proptest
// strategy setup (cf. the params-struct + generator idiom in SNIPPETS.md):
// a per-case params struct is drawn from a seeded RNG, and the invariant is
// asserted on every case with the failing case id in the message.

use adsp::coordinator::ParameterServer;
use adsp::pserver::{Partition, ShardedParameterServer};
use adsp::runtime::ParamSet;

/// Per-case generation parameters (the "strategy" of these proptests).
struct PserverCaseParams {
    leaves: Vec<Vec<f32>>,
    shards: usize,
    pipeline_depth: usize,
    eta: f32,
    mu: f32,
    commits: usize,
}

impl PserverCaseParams {
    fn draw(r: &mut Rng) -> Self {
        let n_leaves = 1 + r.below(7);
        let leaves = (0..n_leaves)
            .map(|_| {
                let len = r.below(40); // zero-length leaves allowed
                (0..len).map(|_| r.normal_f32()).collect()
            })
            .collect();
        PserverCaseParams {
            leaves,
            shards: 1 + r.below(12),
            pipeline_depth: 1 + r.below(4),
            eta: 0.05 + 0.5 * r.next_f32(),
            mu: if r.below(2) == 0 { 0.0 } else { 0.5 + 0.4 * r.next_f32() },
            commits: 1 + r.below(12),
        }
    }

    fn params(&self) -> ParamSet {
        ParamSet { leaves: self.leaves.clone() }
    }

    fn random_update(&self, r: &mut Rng) -> ParamSet {
        ParamSet {
            leaves: self
                .leaves
                .iter()
                .map(|l| l.iter().map(|_| r.normal_f32()).collect())
                .collect(),
        }
    }
}

fn assert_bit_identical(a: &ParamSet, b: &ParamSet, what: &str) {
    assert_eq!(a.leaves.len(), b.leaves.len(), "{what}: leaf count");
    for (i, (la, lb)) in a.leaves.iter().zip(&b.leaves).enumerate() {
        assert_eq!(la.len(), lb.len(), "{what}: leaf {i} length");
        for (j, (x, y)) in la.iter().zip(lb).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: leaf {i} elem {j}: {x} vs {y}"
            );
        }
    }
}

#[test]
fn prop_partition_roundtrip_arbitrary_shapes() {
    let mut rng = Rng::new(0x9A57);
    for case in 0..300u64 {
        let mut r = rng.split(case);
        let p = PserverCaseParams::draw(&mut r).params();
        let s = 1 + r.below(12);
        let part = Partition::for_params(&p, s);
        let slabs = part.split(&p);
        assert_eq!(slabs.len(), s, "case {case}");
        let covered: usize = slabs.iter().map(Vec::len).sum();
        assert_eq!(covered, p.total_numel(), "case {case}: slabs must cover");
        // Contiguous balanced slabs: sizes differ by at most one element.
        let min = slabs.iter().map(Vec::len).min().unwrap();
        let max = slabs.iter().map(Vec::len).max().unwrap();
        assert!(max - min <= 1, "case {case}: unbalanced slabs");
        // partition → reassemble == identity (exact, not approximate).
        assert_bit_identical(&part.reassemble(&slabs), &p, &format!("case {case} s={s}"));
    }
}

#[test]
fn prop_single_shard_apply_matches_parameter_server_exactly() {
    // Acceptance invariant: S = 1 sharded apply is bit-identical to
    // `coordinator::ps::ParameterServer::apply` over an identical commit
    // sequence, on both the plain and the momentum path.
    let mut rng = Rng::new(0x51AB);
    for case in 0..120u64 {
        let mut r = rng.split(case);
        let mut cp = PserverCaseParams::draw(&mut r);
        cp.shards = 1;
        let init = cp.params();
        let mut serial = ParameterServer::new(init.clone(), cp.eta, cp.mu);
        let mut sharded =
            ShardedParameterServer::new(init, cp.eta, cp.mu, cp.shards, cp.pipeline_depth);
        for _ in 0..cp.commits {
            let u = cp.random_update(&mut r);
            serial.apply(&u);
            sharded.apply(&u);
        }
        let (version, got) = sharded.versioned_snapshot();
        assert_eq!(version, cp.commits as u64, "case {case}");
        assert_eq!(sharded.commits, serial.commits, "case {case}");
        assert_bit_identical(
            &got,
            serial.global(),
            &format!("case {case} mu={}", cp.mu),
        );
    }
}

// ---------------------------------------------------------------------------
// Fault subsystem: checkpoint save→restore, fault-event validation
// ---------------------------------------------------------------------------

use adsp::fault::CheckpointStore;

#[test]
fn prop_checkpoint_restore_roundtrip_any_shard_count() {
    // Acceptance invariant: a checkpoint taken at version v restores the
    // server to *exactly* its state at v — bit-identical global AND
    // velocity — for S = 1 and S > 1, momentum included. Velocity
    // recovery is proven by replay equivalence against the serial PS.
    let mut rng = Rng::new(0xC4EC);
    for case in 0..80u64 {
        let mut r = rng.split(case);
        let cp = PserverCaseParams::draw(&mut r);
        let init = cp.params();
        let mut serial = ParameterServer::new(init.clone(), cp.eta, cp.mu);
        let mut sharded =
            ShardedParameterServer::new(init, cp.eta, cp.mu, cp.shards, cp.pipeline_depth);
        for _ in 0..cp.commits {
            let u = cp.random_update(&mut r);
            serial.apply(&u);
            sharded.apply(&u);
        }
        let (v_at, snap_at) = sharded.versioned_snapshot();
        let ckpt = sharded.checkpoint();
        assert_eq!(ckpt.version, v_at, "case {case}");
        assert_bit_identical(&ckpt.params, &snap_at, &format!("case {case} ckpt cut"));
        // Diverge past the checkpoint, then fail over.
        for _ in 0..1 + r.below(5) {
            sharded.apply(&cp.random_update(&mut r));
        }
        sharded.restore(&ckpt);
        let (v_back, snap_back) = sharded.versioned_snapshot();
        assert_eq!(v_back, v_at, "case {case}: version must roll back");
        assert_bit_identical(
            &snap_back,
            &snap_at,
            &format!("case {case} s={} mu={}", cp.shards, cp.mu),
        );
        // Replay equivalence: one more identical commit on the restored
        // server and the serial reference must agree bit for bit — this
        // fails if the velocity was not restored with the cut.
        let u_star = cp.random_update(&mut r);
        serial.apply(&u_star);
        sharded.apply(&u_star);
        assert_bit_identical(
            &sharded.snapshot(),
            serial.global(),
            &format!("case {case} post-restore replay (mu={})", cp.mu),
        );
        // A store retains the cut it was handed.
        let mut store = CheckpointStore::new(2);
        store.save(ckpt);
        assert_eq!(store.latest().unwrap().version, v_at, "case {case}");
    }
}

#[test]
fn prop_timeline_rejects_fault_events_on_departed_or_out_of_range() {
    use adsp::cluster::ClusterEvent as Ev;
    let mut rng = Rng::new(0xFA01);
    for case in 0..150u64 {
        let mut r = rng.split(case);
        let cluster = random_cluster(&mut r);
        let m = cluster.m();
        let shards = 1 + r.below(8);
        // A well-formed crash + failure script validates.
        let ok = adsp::cluster::ClusterTimeline::new(vec![
            Ev::WorkerCrash {
                t: 10.0,
                worker: r.below(m),
                restart_after: 1.0 + 20.0 * r.next_f64(),
            },
            Ev::ShardFailure {
                t: 50.0,
                shard: r.below(shards),
                recover_after: 1.0 + 10.0 * r.next_f64(),
            },
        ]);
        ok.validate_full(m, shards, &[]).unwrap_or_else(|e| panic!("case {case}: {e}"));
        // Crash against a departed worker is rejected (needs m >= 2 so
        // the leave itself is legal).
        if m >= 2 {
            let w = r.below(m);
            let ghost = adsp::cluster::ClusterTimeline::new(vec![
                Ev::WorkerLeave { t: 5.0, worker: w },
                Ev::WorkerCrash { t: 6.0, worker: w, restart_after: 5.0 },
            ]);
            assert!(ghost.validate(m).is_err(), "case {case}: departed crash accepted");
        }
        // Crash against an out-of-range worker is rejected.
        let oob = adsp::cluster::ClusterTimeline::new(vec![Ev::WorkerCrash {
            t: 5.0,
            worker: m + r.below(4),
            restart_after: 5.0,
        }]);
        assert!(oob.validate(m).is_err(), "case {case}: out-of-range crash accepted");
        // Shard failures out of range are rejected exactly at the bound.
        let bad_shard = adsp::cluster::ClusterTimeline::new(vec![Ev::ShardFailure {
            t: 5.0,
            shard: shards + r.below(4),
            recover_after: 5.0,
        }]);
        assert!(
            bad_shard.validate_full(m, shards, &[]).is_err(),
            "case {case}: out-of-range shard accepted"
        );
    }
}

// ---------------------------------------------------------------------------
// Dynamic cluster timelines (cluster subsystem)
// ---------------------------------------------------------------------------

use adsp::cluster::{scenarios, ClusterEvent, ClusterState, ClusterTimeline};
use adsp::config::ExperimentSpec;

#[test]
fn prop_cluster_events_preserve_invariants() {
    // (a) Whatever event stream hits the live state — valid or not (bad
    // targets are rejected with an error) — speeds stay positive, the
    // membership never empties, and the per-worker vectors stay aligned.
    let mut rng = Rng::new(0xD17A);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let cluster = random_cluster(&mut r);
        let mut state =
            ClusterState::new(&cluster, SyncModelKind::Adsp, 32, &[16, 32, 64]);
        let mut t = 0.0;
        for _ in 0..30 {
            t += r.next_f64() * 10.0;
            let ev = match r.below(8) {
                0 => ClusterEvent::SpeedChange {
                    t,
                    worker: r.below(state.m()),
                    speed: 0.1 + 3.0 * r.next_f64(),
                },
                1 => ClusterEvent::CommChange {
                    t,
                    worker: r.below(state.m()),
                    comm_secs: r.next_f64(),
                },
                2 => ClusterEvent::WorkerJoin {
                    t,
                    spec: WorkerSpec::new(0.1 + 2.0 * r.next_f64(), 0.1 + 0.3 * r.next_f64()),
                },
                3 => ClusterEvent::WorkerLeave { t, worker: r.below(state.m()) },
                4 => ClusterEvent::BandwidthChange {
                    t,
                    worker: r.below(state.m()),
                    bandwidth_bytes_per_sec: if r.below(3) == 0 {
                        0.0
                    } else {
                        1e4 + 1e7 * r.next_f64()
                    },
                },
                5 => ClusterEvent::CommBlackout {
                    start: t,
                    duration: 0.5 + 20.0 * r.next_f64(),
                    workers: if r.below(2) == 0 {
                        Vec::new()
                    } else {
                        vec![r.below(state.m())]
                    },
                    cell: None,
                },
                6 => ClusterEvent::WorkerCrash {
                    t,
                    worker: r.below(state.m()),
                    restart_after: 0.5 + 15.0 * r.next_f64(),
                },
                _ => ClusterEvent::ShardFailure {
                    t,
                    shard: 0,
                    recover_after: 0.5 + 10.0 * r.next_f64(),
                },
            };
            let _ = state.apply_event(&ev); // invalid targets must error, not corrupt
            assert!(state.active_count() >= 1, "case {case}: membership emptied");
            assert!(
                state.speeds.iter().all(|&v| v > 0.0 && v.is_finite()),
                "case {case}: non-positive speed crept in"
            );
            assert!(state.comms.iter().all(|&o| o >= 0.0), "case {case}");
            let m = state.m();
            assert_eq!(state.comms.len(), m, "case {case}");
            assert_eq!(state.active.len(), m, "case {case}");
            assert_eq!(state.batch_sizes.len(), m, "case {case}");
            assert_eq!(state.links.len(), m, "case {case}");
            assert_eq!(state.blackout_until.len(), m, "case {case}");
            assert_eq!(state.down_until.len(), m, "case {case}");
            assert_eq!(state.cells.len(), m, "case {case}");
            assert!(
                state.down_until.iter().all(|&d| d >= 0.0 && d.is_finite()),
                "case {case}: bad crash lift time"
            );
            assert!(
                state.shard_down.iter().all(|&d| d >= 0.0 && d.is_finite()),
                "case {case}: bad shard recovery time"
            );
            assert!(
                state.links.iter().map(|l| l.validate()).all(|r| r.is_ok()),
                "case {case}: invalid link crept in"
            );
            assert!(
                state.blackout_until.iter().all(|&b| b >= 0.0 && b.is_finite()),
                "case {case}: bad blackout lift time"
            );
        }
    }
}

#[test]
fn prop_timeline_json_roundtrips_through_spec() {
    // (c) Random *valid* timelines survive the ExperimentSpec JSON cycle
    // exactly (event order, kinds, and float payloads).
    let mut rng = Rng::new(0x71AE);
    for case in 0..150u64 {
        let mut r = rng.split(case);
        let cluster = random_cluster(&mut r);
        let mut active = vec![true; cluster.m()];
        let mut t = 0.0;
        let mut events = Vec::new();
        for _ in 0..r.below(12) {
            t += 0.5 + r.next_f64() * 20.0;
            let alive: Vec<usize> =
                (0..active.len()).filter(|&w| active[w]).collect();
            match r.below(6) {
                0 => events.push(ClusterEvent::SpeedChange {
                    t,
                    worker: alive[r.below(alive.len())],
                    speed: 0.1 + 3.0 * r.next_f64(),
                }),
                1 => events.push(ClusterEvent::CommChange {
                    t,
                    worker: alive[r.below(alive.len())],
                    comm_secs: r.next_f64(),
                }),
                2 => {
                    events.push(ClusterEvent::WorkerJoin {
                        t,
                        spec: WorkerSpec::new(0.2 + r.next_f64(), 0.1),
                    });
                    active.push(true);
                }
                3 => events.push(ClusterEvent::BandwidthChange {
                    t,
                    worker: alive[r.below(alive.len())],
                    bandwidth_bytes_per_sec: 1e5 * (1.0 + r.below(100) as f64),
                }),
                4 => events.push(ClusterEvent::CommBlackout {
                    start: t,
                    duration: 0.5 + 30.0 * r.next_f64(),
                    workers: if r.below(2) == 0 {
                        Vec::new()
                    } else {
                        vec![alive[r.below(alive.len())]]
                    },
                    cell: None,
                }),
                _ => {
                    if alive.len() > 1 {
                        let w = alive[r.below(alive.len())];
                        events.push(ClusterEvent::WorkerLeave { t, worker: w });
                        active[w] = false;
                    }
                }
            }
        }
        let mut spec = ExperimentSpec::new(
            "mlp_quick",
            cluster,
            SyncSpec::new(SyncModelKind::Adsp),
        );
        spec.timeline = ClusterTimeline::new(events);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: generated invalid: {e}"));
        let back = ExperimentSpec::from_json_str(&spec.to_json().dump_pretty())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back.timeline, spec.timeline, "case {case}");
    }
}

#[test]
fn prop_policies_survive_churn() {
    // Mid-run leave + join must not deadlock any policy: barriers rebuild
    // over the active membership, the joiner (bootstrapped to the active
    // minimum) participates in rounds, and progress continues.
    let mut rng = Rng::new(0xC4A2);
    let kinds = [
        SyncModelKind::Bsp,
        SyncModelKind::Ssp,
        SyncModelKind::FixedAdacomm,
        SyncModelKind::Adacomm,
        SyncModelKind::Adsp,
        SyncModelKind::AdspPlus,
    ];
    for kind in kinds {
        for case in 0..40 {
            let mut case_rng = rng.split(case as u64);
            let cluster = random_cluster(&mut case_rng);
            let sync = random_sync(&mut case_rng, kind);
            let mut eng = MockEngine::new(kind, &cluster, &sync);
            eng.churn_at = Some(30.0 + 150.0 * case_rng.next_f64());
            eng.join_speed = 0.3 + 3.0 * case_rng.next_f64();
            let ok = eng.run(400.0, |_, _| {});
            assert!(ok, "case {case}: {kind} deadlocked after churn");
            assert!(eng.churn_at.is_none(), "case {case}: churn never fired");
            // The joiner really trained past its bootstrap point.
            let boot = eng.joined_at_steps.expect("join recorded");
            let j = eng.progress.len() - 1;
            assert!(eng.progress.is_active(j));
            assert!(
                eng.progress.steps(j) > boot,
                "case {case}: {kind} joiner never trained ({} <= {boot})",
                eng.progress.steps(j)
            );
            // Active workers kept committing.
            assert!(
                (0..eng.progress.len())
                    .any(|i| eng.progress.is_active(i) && eng.progress.commits(i) > 0),
                "case {case}: {kind} cluster stopped committing"
            );
        }
    }
}

#[test]
fn prop_scenario_presets_validate_at_any_size() {
    let mut rng = Rng::new(0x5CE2);
    for case in 0..100u64 {
        let mut r = rng.split(case);
        let cluster = random_cluster(&mut r);
        let horizon = 100.0 + 900.0 * r.next_f64();
        for name in scenarios::SCENARIO_NAMES {
            let tl = scenarios::preset(name, &cluster, horizon)
                .unwrap_or_else(|e| panic!("case {case} {name}: {e}"));
            tl.validate(cluster.m())
                .unwrap_or_else(|e| panic!("case {case} {name}: {e}"));
        }
    }
}

#[test]
fn prop_sharded_apply_bit_identical_for_any_shard_count() {
    // The element-wise update rules make this hold for every S, not just 1;
    // pin it so future shard-local optimizations cannot silently reorder
    // the float math.
    let mut rng = Rng::new(0x5EAF);
    for case in 0..80u64 {
        let mut r = rng.split(case);
        let cp = PserverCaseParams::draw(&mut r);
        let init = cp.params();
        let mut serial = ParameterServer::new(init.clone(), cp.eta, cp.mu);
        let mut sharded =
            ShardedParameterServer::new(init, cp.eta, cp.mu, cp.shards, cp.pipeline_depth);
        assert_eq!(sharded.num_shards(), cp.shards, "case {case}");
        for _ in 0..cp.commits {
            let u = cp.random_update(&mut r);
            serial.apply(&u);
            sharded.apply(&u);
        }
        assert_bit_identical(
            &sharded.snapshot(),
            serial.global(),
            &format!("case {case} s={} mu={}", cp.shards, cp.mu),
        );
    }
}

// ---------------------------------------------------------------------------
// network layer: links, contention, blackout specs
// ---------------------------------------------------------------------------

use adsp::network::{IngressDiscipline, IngressQueue, LinkModel, NetworkSpec};

#[test]
fn prop_transfer_time_monotone_in_bytes_and_inverse_in_bandwidth() {
    // More bytes never transfer faster; more bandwidth never transfers
    // slower (latency and jitter-free paths held fixed).
    let mut rng = Rng::new(0x11A7);
    for case in 0..300u64 {
        let mut r = rng.split(case);
        let latency = r.next_f64() * 0.5;
        let bw_lo = 1e3 + 1e6 * r.next_f64();
        let bw_hi = bw_lo * (1.0 + 4.0 * r.next_f64());
        let bytes_a = r.next_u64() % 10_000_000;
        let bytes_b = bytes_a + r.next_u64() % 10_000_000;
        let slow = LinkModel { bandwidth_bytes_per_sec: bw_lo, latency_secs: latency, jitter: 0.0 };
        let fast = LinkModel { bandwidth_bytes_per_sec: bw_hi, latency_secs: latency, jitter: 0.0 };
        // Monotone in payload bytes.
        assert!(
            slow.transfer_secs(bytes_b) >= slow.transfer_secs(bytes_a),
            "case {case}: {bytes_b} B transferred faster than {bytes_a} B"
        );
        // Inversely monotone in bandwidth.
        assert!(
            fast.transfer_secs(bytes_b) <= slow.transfer_secs(bytes_b),
            "case {case}: more bandwidth made the transfer slower"
        );
        // The unbounded link lower-bounds everything at its latency.
        let free = LinkModel { bandwidth_bytes_per_sec: 0.0, latency_secs: latency, jitter: 0.0 };
        assert!(free.transfer_secs(bytes_b) <= fast.transfer_secs(bytes_b) + 1e-12);
        assert!((free.transfer_secs(bytes_b) - latency).abs() < 1e-12);
    }
}

#[test]
fn prop_ingress_admission_is_sane_under_random_traffic() {
    // For both disciplines: completions never precede arrivals, an
    // unbounded queue is the identity, and FIFO completions are monotone
    // in admission order (the pipe never reorders commits).
    let mut rng = Rng::new(0x1264);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let capacity = 1e4 + 1e7 * r.next_f64();
        let mut fifo = IngressQueue::new(capacity, IngressDiscipline::Fifo);
        let mut fair = IngressQueue::new(capacity, IngressDiscipline::FairShare);
        let mut open = IngressQueue::unbounded();
        let mut t = 0.0;
        let mut last_fifo_done = 0.0;
        for _ in 0..50 {
            t += r.next_f64();
            let bytes = r.next_u64() % 5_000_000;
            let f = fifo.admit(t, bytes);
            assert!(f >= t, "case {case}: FIFO finished before arrival");
            assert!(f >= last_fifo_done, "case {case}: FIFO reordered commits");
            last_fifo_done = f;
            let s = fair.admit(t, bytes);
            assert!(s >= t, "case {case}: fair share finished before arrival");
            assert!(
                s >= t + bytes as f64 / capacity - 1e-9,
                "case {case}: fair share beat the uncontended service time"
            );
            assert_eq!(open.admit(t, bytes), t, "case {case}: unbounded delayed a commit");
        }
    }
}

#[test]
fn prop_blackout_and_network_sections_roundtrip_through_spec_json() {
    // Random network sections + blackout-bearing timelines survive the
    // ExperimentSpec JSON cycle exactly.
    let mut rng = Rng::new(0xB1AC);
    for case in 0..150u64 {
        let mut r = rng.split(case);
        let cluster = random_cluster(&mut r);
        let m = cluster.m();
        let mut spec =
            ExperimentSpec::new("mlp_quick", cluster, SyncSpec::new(SyncModelKind::Adsp));
        spec.network = NetworkSpec {
            default_link: LinkModel {
                bandwidth_bytes_per_sec: if r.below(3) == 0 {
                    0.0
                } else {
                    1e4 + 1e7 * r.next_f64()
                },
                latency_secs: 0.25 * r.next_f64(),
                jitter: if r.below(2) == 0 { 0.0 } else { 0.5 * r.next_f64() },
            },
            links: if r.below(2) == 0 {
                Vec::new()
            } else {
                (0..m)
                    .map(|_| LinkModel::with_bandwidth(1e5 * (1.0 + r.below(50) as f64)))
                    .collect()
            },
            ingress_bytes_per_sec: if r.below(2) == 0 { 0.0 } else { 1e6 + 1e8 * r.next_f64() },
            ingress_discipline: if r.below(2) == 0 {
                IngressDiscipline::Fifo
            } else {
                IngressDiscipline::FairShare
            },
        };
        let mut events = Vec::new();
        let mut t = 0.0;
        for _ in 0..r.below(6) {
            t += 1.0 + 20.0 * r.next_f64();
            events.push(ClusterEvent::CommBlackout {
                start: t,
                duration: 0.5 + 30.0 * r.next_f64(),
                workers: match r.below(3) {
                    0 => Vec::new(),
                    1 => vec![r.below(m)],
                    _ => (0..m).filter(|_| r.below(2) == 0).collect(),
                },
                cell: None,
            });
            t += 1.0;
            events.push(ClusterEvent::BandwidthChange {
                t,
                worker: r.below(m),
                bandwidth_bytes_per_sec: 1e5 * (1.0 + r.below(100) as f64),
            });
        }
        spec.timeline = ClusterTimeline::new(events);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: generated invalid: {e}"));
        let back = ExperimentSpec::from_json_str(&spec.to_json().dump_pretty())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(back.network, spec.network, "case {case}: network section drifted");
        assert_eq!(back.timeline, spec.timeline, "case {case}: blackout timeline drifted");
    }
}

// ---------------------------------------------------------------------------
// run report: randomized JSON round-trip
// ---------------------------------------------------------------------------

use adsp::metrics::{Breakdown, LossLog, WorkerMetrics};
use adsp::obs::{AttributionLedger, MetricsRegistry, TimeClass};
use adsp::run::{EngineStats, RunReport};

/// A random metrics registry with finite gauges only — the serializer
/// writes NaN/Inf as JSON `null`, which by design cannot round-trip, so
/// randomized round-trip cases stay in the finite domain.
fn random_registry(r: &mut Rng) -> MetricsRegistry {
    let mut reg = MetricsRegistry::new();
    for i in 0..r.below(6) {
        reg.add(&format!("c/val{i}"), r.next_u64() >> 14);
    }
    for i in 0..r.below(4) {
        reg.set_gauge(&format!("g/val{i}"), (r.next_f64() - 0.5) * 1e6);
    }
    for i in 0..r.below(3) {
        let name = format!("h/val{i}");
        for _ in 0..r.below(40) {
            reg.observe(&name, r.next_f64() * 10.0);
        }
    }
    reg
}

/// A random attribution section built through the ledger itself, so it is
/// conservation-consistent by construction (random charges, idle gaps,
/// sometimes streamed above the cap).
fn random_attribution(r: &mut Rng) -> adsp::obs::AttributionReport {
    let m = 1 + r.below(4);
    let horizon = 50.0 + 200.0 * r.next_f64();
    let mut ledger = AttributionLedger::new(m, horizon);
    for w in 0..m {
        let mut t = 0.0;
        while t < horizon {
            let dt = 0.5 + 5.0 * r.next_f64();
            let class = TimeClass::CHARGED[r.below(TimeClass::CHARGED.len())];
            ledger.charge(w, class, t, t + dt);
            t += dt + r.next_f64(); // leave occasional idle gaps
        }
    }
    ledger.finalize(horizon, if r.below(4) == 0 { 0 } else { 1 << 20 })
}

/// A random, finite-valued report covering both engine variants, empty and
/// populated logs, converged and capped runs.
fn random_report(r: &mut Rng) -> RunReport {
    let signed = |r: &mut Rng, scale: f64| (r.next_f64() - 0.5) * 2.0 * scale;
    let m = r.below(5);
    let workers: Vec<WorkerMetrics> = (0..m)
        .map(|_| WorkerMetrics {
            compute_secs: r.next_f64() * 500.0,
            comm_secs: r.next_f64() * 50.0,
            blocked_secs: r.next_f64() * 50.0,
            steps: r.next_u64() >> 14, // < 2^50: exact as a JSON number
            commits: r.next_u64() >> 14,
            bytes_up: r.next_u64() >> 14,
            bytes_down: r.next_u64() >> 14,
        })
        .collect();
    let mut loss_log = LossLog::default();
    for i in 0..r.below(12) {
        loss_log.push(
            i as f64 * (1.0 + r.next_f64()),
            (i as u64) * 17,
            signed(r, 10.0),
            r.next_f64(),
        );
    }
    let kind = SyncModelKind::ALL[r.below(SyncModelKind::ALL.len())];
    let engine = if r.below(2) == 0 {
        EngineStats::Sim {
            xla_execs: r.next_u64() >> 14,
            xla_secs: r.next_f64() * 100.0,
            deadlocked: r.below(2) == 0,
            dropped_commits: r.next_u64() >> 40,
            events_processed: r.next_u64() >> 14,
        }
    } else {
        EngineStats::Realtime { time_scale: 0.001 + r.next_f64() }
    };
    RunReport {
        model: format!("model_{}", r.below(100)),
        sync: kind,
        sync_describe: format!("{} C_target={}", kind.name(), r.below(32)),
        converged_at: if r.below(2) == 0 { Some(r.next_f64() * 3600.0) } else { None },
        end_time: r.next_f64() * 3600.0,
        wall_secs: r.next_f64() * 100.0,
        total_steps: r.next_u64() >> 14,
        total_commits: r.next_u64() >> 14,
        final_loss: signed(r, 10.0),
        best_loss: signed(r, 10.0),
        final_accuracy: r.next_f64(),
        loss_log,
        workers,
        breakdown: Breakdown {
            avg_compute_secs: r.next_f64() * 500.0,
            avg_waiting_secs: r.next_f64() * 100.0,
            avg_comm_secs: r.next_f64() * 50.0,
            avg_blocked_secs: r.next_f64() * 50.0,
        },
        bytes_total: r.next_u64() >> 14,
        wasted_steps: r.next_u64() >> 40,
        lost_commits: r.next_u64() >> 40,
        checkpoints_taken: r.next_u64() >> 40,
        checkpoint_overhead_secs: r.next_f64() * 60.0,
        metrics: if r.below(3) == 0 { None } else { Some(random_registry(r)) },
        attribution: if r.below(3) == 0 { None } else { Some(random_attribution(r)) },
        engine,
    }
}

#[test]
fn run_report_json_roundtrip_is_lossless() {
    // Rust's f64 Display prints the shortest representation that parses
    // back to the same bits, so dump → parse must be bit-lossless for
    // every finite field, and structurally exact for everything else.
    let mut rng = Rng::new(0x5EED_4E50); // "REPO(rt)" seed
    for case in 0..300 {
        let report = random_report(&mut rng);
        let text = if case % 2 == 0 {
            report.to_json().dump_pretty()
        } else {
            report.to_json().dump()
        };
        let back = RunReport::from_json_str(&text)
            .unwrap_or_else(|e| panic!("case {case}: parse failed: {e}"));
        assert_eq!(
            back.to_json(),
            report.to_json(),
            "case {case}: JSON round trip drifted"
        );
        assert_eq!(back.sync, report.sync, "case {case}");
        assert_eq!(back.engine, report.engine, "case {case}: engine stats drifted");
        assert_eq!(
            back.end_time.to_bits(),
            report.end_time.to_bits(),
            "case {case}: end_time bits"
        );
        assert_eq!(
            back.final_loss.to_bits(),
            report.final_loss.to_bits(),
            "case {case}: final_loss bits"
        );
        assert_eq!(
            back.converged_at.map(f64::to_bits),
            report.converged_at.map(f64::to_bits),
            "case {case}: converged_at"
        );
        assert_eq!(back.workers.len(), report.workers.len(), "case {case}");
        for (a, b) in back.workers.iter().zip(&report.workers) {
            assert_eq!(a.compute_secs.to_bits(), b.compute_secs.to_bits(), "case {case}");
            assert_eq!(a.steps, b.steps, "case {case}");
            assert_eq!(a.bytes_up, b.bytes_up, "case {case}");
        }
        assert_eq!(
            back.loss_log.samples.len(),
            report.loss_log.samples.len(),
            "case {case}"
        );
        for (a, b) in back.loss_log.samples.iter().zip(&report.loss_log.samples) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "case {case}: loss bits");
            assert_eq!(a.t.to_bits(), b.t.to_bits(), "case {case}: t bits");
        }
    }
}

// ---------------------------------------------------------------------------
// observability: randomized trace + registry round trips
// ---------------------------------------------------------------------------

use adsp::obs::{TraceEvent, TraceRecorder};

#[test]
fn prop_trace_jsonl_roundtrip_is_lossless_and_time_ordered() {
    // Random event streams — out-of-order stamps, occasional NaN, small
    // ring capacities — must (a) come out monotonically time-ordered,
    // (b) respect the capacity with exact dropped accounting, and
    // (c) survive the JSONL dump → parse cycle bit-exactly.
    let mut rng = Rng::new(0x7_2ACE);
    let kinds = ["commit", "eval", "cluster", "checkpoint", "run_end"];
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let capacity = 1 + r.below(24);
        let total = r.below(64);
        let mut tr = TraceRecorder::new(capacity);
        for i in 0..total {
            // Mostly increasing, sometimes jumping backwards, rarely NaN.
            let t = match r.below(10) {
                0 => f64::NAN,
                1..=2 => r.next_f64() * 5.0, // may land before last_t
                _ => i as f64 + r.next_f64(),
            };
            let data = vec![("i", Json::Num(i as f64))];
            tr.record(t, r.next_f64() * 3.0, kinds[r.below(kinds.len())], data);
        }
        assert!(tr.len() <= capacity, "case {case}: ring overflowed");
        assert_eq!(
            tr.len() as u64 + tr.dropped(),
            total as u64,
            "case {case}: dropped accounting broken"
        );
        let events: Vec<TraceEvent> = tr.events().cloned().collect();
        for pair in events.windows(2) {
            assert!(
                pair[0].t <= pair[1].t,
                "case {case}: stream not monotone ({} > {})",
                pair[0].t,
                pair[1].t
            );
            assert!(pair[0].t.is_finite(), "case {case}: non-finite stamp survived");
        }
        let back = TraceRecorder::parse_jsonl(&tr.to_jsonl())
            .unwrap_or_else(|e| panic!("case {case}: parse failed: {e}"));
        assert_eq!(back, events, "case {case}: JSONL round trip drifted");
        for (a, b) in back.iter().zip(&events) {
            assert_eq!(a.t.to_bits(), b.t.to_bits(), "case {case}: t bits");
            assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits(), "case {case}: wall_s bits");
        }
    }
}

// ---------------------------------------------------------------------------
// cohorts: deterministic fleet expansion (config subsystem)
// ---------------------------------------------------------------------------

use adsp::config::{CohortSpec, Dist};

fn random_dist(r: &mut Rng) -> Dist {
    match r.below(3) {
        0 => Dist::Point(0.2 + 3.0 * r.next_f64()),
        1 => {
            let lo = 0.05 + r.next_f64();
            Dist::Uniform { lo, hi: lo + r.next_f64() }
        }
        _ => Dist::LogNormal {
            median: 0.3 + 2.0 * r.next_f64(),
            sigma: 0.1 + 0.8 * r.next_f64(),
        },
    }
}

fn random_cohort_spec(r: &mut Rng) -> ExperimentSpec {
    let explicit = (0..r.below(3))
        .map(|_| WorkerSpec::new(0.5 + r.next_f64(), 0.1 + 0.2 * r.next_f64()))
        .collect();
    let cohorts: Vec<CohortSpec> = (1..=1 + r.below(3))
        .map(|_| {
            let mut c =
                CohortSpec::new(1 + r.below(40), random_dist(r), random_dist(r));
            c.batch_size = [0, 32, 64][r.below(3)];
            c.cells = (0..r.below(4)).map(|i| format!("cell-{i}")).collect();
            c
        })
        .collect();
    let cluster = ClusterSpec::new(explicit).with_cohorts(cohorts);
    let mut spec =
        ExperimentSpec::new("mlp_quick", cluster, SyncSpec::new(SyncModelKind::Adsp));
    spec.seed = r.next_u64();
    spec
}

fn assert_same_workers(a: &[WorkerSpec], b: &[WorkerSpec], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: worker count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.speed.to_bits(), y.speed.to_bits(), "{what}: worker {i} speed");
        assert_eq!(
            x.comm_secs.to_bits(),
            y.comm_secs.to_bits(),
            "{what}: worker {i} comm"
        );
        assert_eq!(x.batch_size, y.batch_size, "{what}: worker {i} batch");
        assert_eq!(x.cell, y.cell, "{what}: worker {i} cell");
    }
}

#[test]
fn prop_cohort_expansion_is_deterministic_and_well_formed() {
    let mut rng = Rng::new(0xC0_4027);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let spec = random_cohort_spec(&mut r);
        let explicit = spec.cluster.workers.len();
        let want: usize = explicit + spec.cluster.cohorts.iter().map(|c| c.count).sum::<usize>();
        let ex1 = spec.expanded().unwrap_or_else(|e| panic!("case {case}: {e}")).unwrap();
        let ex2 = spec.expanded().unwrap().unwrap();
        // Exactly N members, same fleet bit-for-bit on every expansion.
        assert_eq!(ex1.cluster.workers.len(), want, "case {case}");
        assert!(ex1.cluster.cohorts.is_empty(), "case {case}: cohorts survived expansion");
        assert_same_workers(&ex1.cluster.workers, &ex2.cluster.workers, &format!("case {case}"));
        // Members are appended after the explicit workers, which expansion
        // must never touch.
        assert_same_workers(
            &ex1.cluster.workers[..explicit],
            &spec.cluster.workers,
            &format!("case {case} explicit prefix"),
        );
        // Every sampled attribute is physically valid, cells round-robin.
        let mut off = explicit;
        for (ci, c) in spec.cluster.cohorts.iter().enumerate() {
            for i in 0..c.count {
                let w = &ex1.cluster.workers[off + i];
                assert!(
                    w.speed > 0.0 && w.speed.is_finite(),
                    "case {case}: cohort {ci} member {i} speed {}",
                    w.speed
                );
                assert!(w.comm_secs >= 0.0 && w.comm_secs.is_finite(), "case {case}");
                assert_eq!(w.batch_size, c.batch_size, "case {case}");
                let want_cell = if c.cells.is_empty() {
                    String::new()
                } else {
                    c.cells[i % c.cells.len()].clone()
                };
                assert_eq!(w.cell, want_cell, "case {case}: cohort {ci} member {i} cell");
            }
            off += c.count;
        }
        // A different seed reshuffles any non-degenerate fleet expansion
        // RNG stream (point-only cohorts never touch the RNG, so only
        // check when some distribution actually samples).
        let mut other = spec.clone();
        other.seed = spec.seed.wrapping_add(1);
        let ex3 = other.expanded().unwrap().unwrap();
        assert_eq!(ex3.cluster.workers.len(), want, "case {case}");
        // Expansion-then-validate succeeds (the generated fleets are legal).
        ex1.validate().unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn prop_cohort_specs_roundtrip_through_json() {
    let mut rng = Rng::new(0xC0_4028);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let spec = random_cohort_spec(&mut r);
        let back = ExperimentSpec::from_json_str(&spec.to_json().dump_pretty())
            .unwrap_or_else(|e| panic!("case {case}: {e}"));
        assert_eq!(
            back.to_json(),
            spec.to_json(),
            "case {case}: cohort spec JSON drifted"
        );
        // The round-tripped spec expands to the identical fleet.
        let a = spec.expanded().unwrap().unwrap();
        let b = back.expanded().unwrap().unwrap();
        assert_same_workers(&a.cluster.workers, &b.cluster.workers, &format!("case {case}"));
    }
}

#[test]
fn prop_degenerate_cohort_equals_explicit_workers() {
    // A `count: n` cohort of point distributions is spec-sugar: expansion
    // must yield exactly the worker list a hand-written spec would carry,
    // bit for bit (the premise of the engine-level identity pin in the
    // integration tests).
    let mut rng = Rng::new(0xC0_4029);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let n = 1 + r.below(30);
        let speed = 0.2 + 3.0 * r.next_f64();
        let comm = 0.05 + 0.4 * r.next_f64();
        let batch = [0usize, 32, 64][r.below(3)];
        let mut cohort = CohortSpec::new(n, Dist::Point(speed), Dist::Point(comm));
        cohort.batch_size = batch;
        let mut cohort_spec = ExperimentSpec::new(
            "mlp_quick",
            ClusterSpec::new(Vec::new()).with_cohorts(vec![cohort]),
            SyncSpec::new(SyncModelKind::Adsp),
        );
        cohort_spec.seed = r.next_u64();
        let explicit: Vec<WorkerSpec> = (0..n)
            .map(|_| {
                let mut w = WorkerSpec::new(speed, comm);
                w.batch_size = batch;
                w
            })
            .collect();
        let ex = cohort_spec.expanded().unwrap().unwrap();
        assert_same_workers(&ex.cluster.workers, &explicit, &format!("case {case}"));
    }
}

// ---------------------------------------------------------------------------
// WorkerSlabs: incremental aggregates vs a naive mirror (state machine)
// ---------------------------------------------------------------------------

#[test]
fn prop_worker_slabs_aggregates_match_naive_mirror() {
    // Drives a random op soup — push / bump_steps / bump_commits /
    // set_blocked / set_active / set_steps / set_commits / set_record —
    // under the engines' discipline (blocked only while active; unblock
    // before deactivating), checking after EVERY op that the amortized
    // O(1) aggregates equal a naive recomputation over a mirror vector,
    // that scan_aggregates() agrees with the incremental counters, and
    // that blocked ⊆ active is preserved.
    let mut rng = Rng::new(0x51AB5);
    for case in 0..120u64 {
        let mut r = rng.split(case);
        let mut slabs = WorkerSlabs::new();
        let mut mirror: Vec<WorkerProgress> = Vec::new();
        // Seed 1-4 initial workers.
        for _ in 0..1 + r.below(4) {
            let rec = WorkerProgress {
                steps: r.below(50) as u64,
                commits: r.below(20) as u64,
                local_since_commit: r.below(8) as u64,
                batch_size: [0, 32, 64][r.below(3)],
                blocked: false,
                active: true,
            };
            slabs.push(rec.clone());
            mirror.push(rec);
        }
        for op in 0..200 {
            let m = mirror.len();
            match r.below(10) {
                0 if m < 12 => {
                    let active = r.below(4) != 0;
                    let rec = WorkerProgress {
                        steps: r.below(50) as u64,
                        commits: r.below(20) as u64,
                        local_since_commit: 0,
                        batch_size: 32,
                        blocked: active && r.below(4) == 0,
                        active,
                    };
                    slabs.push(rec.clone());
                    mirror.push(rec);
                }
                1..=3 => {
                    let w = r.below(m);
                    let k = 1 + r.below(4) as u64;
                    slabs.bump_steps(w, k);
                    mirror[w].steps += k;
                }
                4..=5 => {
                    let w = r.below(m);
                    slabs.bump_commits(w);
                    mirror[w].commits += 1;
                }
                6 => {
                    let w = r.below(m);
                    if mirror[w].active {
                        let b = r.below(2) == 0;
                        slabs.set_blocked(w, b);
                        mirror[w].blocked = b;
                    }
                }
                7 => {
                    let w = r.below(m);
                    let a = r.below(2) == 0;
                    if !a {
                        // Blocked is a sub-state of active: clear it first.
                        slabs.set_blocked(w, false);
                        mirror[w].blocked = false;
                    }
                    slabs.set_active(w, a);
                    mirror[w].active = a;
                }
                8 => {
                    let w = r.below(m);
                    let v = r.below(100) as u64;
                    if r.below(2) == 0 {
                        slabs.set_steps(w, v);
                        mirror[w].steps = v;
                    } else {
                        slabs.set_commits(w, v);
                        mirror[w].commits = v;
                    }
                }
                _ => {
                    let w = r.below(m);
                    let active = r.below(4) != 0;
                    let rec = WorkerProgress {
                        steps: r.below(100) as u64,
                        commits: r.below(40) as u64,
                        local_since_commit: r.below(8) as u64,
                        batch_size: 32,
                        blocked: active && r.below(4) == 0,
                        active,
                    };
                    slabs.set_record(w, rec.clone());
                    mirror[w] = rec;
                }
            }
            // Naive recomputation over the mirror.
            let naive_active = mirror.iter().filter(|p| p.active).count();
            let naive_blocked = mirror.iter().filter(|p| p.blocked).count();
            let naive_min_steps =
                mirror.iter().filter(|p| p.active).map(|p| p.steps).min().unwrap_or(0);
            let naive_min_commits =
                mirror.iter().filter(|p| p.active).map(|p| p.commits).min().unwrap_or(0);
            let naive_max_commits =
                mirror.iter().filter(|p| p.active).map(|p| p.commits).max().unwrap_or(0);
            assert_eq!(slabs.len(), mirror.len(), "case {case} op {op}: len");
            assert_eq!(
                slabs.active_count(),
                naive_active,
                "case {case} op {op}: active_count"
            );
            assert_eq!(
                slabs.blocked_count(),
                naive_blocked,
                "case {case} op {op}: blocked_count"
            );
            assert_eq!(
                slabs.min_steps(),
                naive_min_steps,
                "case {case} op {op}: min_steps diverged from naive scan"
            );
            assert_eq!(
                slabs.min_commits(),
                naive_min_commits,
                "case {case} op {op}: min_commits diverged from naive scan"
            );
            assert_eq!(
                slabs.max_commits(),
                naive_max_commits,
                "case {case} op {op}: max_commits diverged from naive scan"
            );
            // The verification scan agrees with the incremental counters.
            assert_eq!(
                slabs.scan_aggregates(),
                (naive_active, naive_min_steps, naive_min_commits, naive_max_commits),
                "case {case} op {op}: scan_aggregates disagrees"
            );
            // Discipline held: blocked ⊆ active, and per-slot state mirrors.
            for w in 0..mirror.len() {
                if slabs.is_blocked(w) {
                    assert!(slabs.is_active(w), "case {case} op {op}: blocked ⊄ active");
                }
                assert_eq!(slabs.is_active(w), mirror[w].active, "case {case} op {op}");
                assert_eq!(slabs.is_blocked(w), mirror[w].blocked, "case {case} op {op}");
                assert_eq!(slabs.steps(w), mirror[w].steps, "case {case} op {op}");
                assert_eq!(slabs.commits(w), mirror[w].commits, "case {case} op {op}");
                let rec = slabs.record(w);
                assert_eq!(rec.steps, mirror[w].steps, "case {case} op {op}: record");
                assert_eq!(rec.commits, mirror[w].commits, "case {case} op {op}: record");
                assert_eq!(
                    rec.local_since_commit, mirror[w].local_since_commit,
                    "case {case} op {op}: record"
                );
                assert_eq!(rec.batch_size, mirror[w].batch_size, "case {case} op {op}");
            }
        }
        // Rebuilding from records reproduces the same aggregates.
        let rebuilt = WorkerSlabs::from_records(&mirror);
        assert_eq!(rebuilt.scan_aggregates(), slabs.scan_aggregates(), "case {case}");
        assert_eq!(rebuilt.active_count(), slabs.active_count(), "case {case}");
        assert_eq!(rebuilt.blocked_count(), slabs.blocked_count(), "case {case}");
    }
}

#[test]
fn prop_metrics_registry_json_roundtrip_is_lossless() {
    // Registry snapshots (counters, finite gauges, histograms on the
    // default latency buckets) survive the JSON cycle exactly — the
    // contract behind comparing two runs' dumped `--metrics` files.
    let mut rng = Rng::new(0x0B5_0B5);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let reg = random_registry(&mut r);
        let text = if case % 2 == 0 {
            reg.to_json().dump_pretty()
        } else {
            reg.to_json().dump()
        };
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}"));
        let back = MetricsRegistry::from_json(&parsed)
            .unwrap_or_else(|e| panic!("case {case}: from_json failed: {e}"));
        assert_eq!(back, reg, "case {case}: registry round trip drifted");
        // The deterministic view of a wall/-free registry is itself.
        assert_eq!(reg.deterministic_view(), reg, "case {case}: view dropped entries");
    }
}

// ---------------------------------------------------------------------------
// attribution: the time ledger conserves every second
// ---------------------------------------------------------------------------

use adsp::cluster::{random_fleet_spec, FuzzIntensity};
use adsp::run::{check_report_invariants, Backend, Run};

fn assert_conserves(rep: &adsp::obs::AttributionReport, what: &str) {
    let tol = |x: f64| 1e-9 * x.abs().max(1.0);
    assert!(rep.duration.is_finite() && rep.duration >= 0.0, "{what}: bad duration");
    for (w, row) in rep.workers.iter().enumerate() {
        for (c, v) in row.iter().enumerate() {
            assert!(v.is_finite() && *v >= 0.0, "{what}: worker {w} class {c} = {v}");
        }
        let sum: f64 = row.iter().sum();
        assert!(
            (sum - rep.duration).abs() <= tol(rep.duration),
            "{what}: worker {w} sums to {sum} != duration {}",
            rep.duration
        );
    }
    let total: f64 = rep.total.iter().sum();
    let want = rep.duration * rep.num_workers as f64;
    assert!(
        (total - want).abs() <= tol(want),
        "{what}: total sums to {total} != m * duration {want}"
    );
}

#[test]
fn prop_attribution_ledger_conserves_under_adversarial_charges() {
    // Charge soups the engines never produce — overlapping intervals,
    // reversed endpoints, spans beyond the horizon, duplicate classes —
    // must still come out conserving: the frontier clamp eats overlaps,
    // the horizon clamp eats overshoot, and idle absorbs the rest, so
    // every worker row sums exactly to the run duration.
    let mut rng = Rng::new(0xA77_2);
    for case in 0..200u64 {
        let mut r = rng.split(case);
        let m = 1 + r.below(6);
        let horizon = 10.0 + 100.0 * r.next_f64();
        let mut ledger = AttributionLedger::new(m, horizon);
        for _ in 0..r.below(80) {
            let w = r.below(m);
            let class = TimeClass::CHARGED[r.below(TimeClass::CHARGED.len())];
            let a = r.next_f64() * horizon * 1.3 - 0.1 * horizon; // may be < 0
            let b = a + (r.next_f64() - 0.2) * 20.0; // may be < a
            ledger.charge(w, class, a, b);
        }
        let end_time = r.next_f64() * horizon * 1.2;
        let rep = ledger.finalize(end_time, if r.below(5) == 0 { 0 } else { 1 << 20 });
        assert!(rep.duration >= end_time - 1e-12, "case {case}: duration below end_time");
        assert_conserves(&rep, &format!("case {case}"));
    }
}

#[test]
fn prop_sim_attribution_conserves_under_random_timelines() {
    // The engine-level guarantee behind `adsp analyze`: for every sync
    // policy, on fuzzed fleets with churn / crashes / blackouts / random
    // networks, the report's attribution section classifies every
    // simulated second into exactly one class — checked here via the
    // oracle (which enforces row-sum == duration) plus a direct
    // conservation pass over the materialized rows.
    let mut case = 0u64;
    for kind in SyncModelKind::ALL {
        for intensity in [FuzzIntensity::Light, FuzzIntensity::Heavy] {
            for s in 0..12u64 {
                case += 1;
                let seed = 0xA77 + case * 7919 + s;
                let spec = random_fleet_spec(seed, kind, intensity);
                let report = Run::from_spec(spec.clone())
                    .backend(Backend::Sim)
                    .execute()
                    .unwrap_or_else(|e| panic!("seed {seed} {kind}: run failed: {e}"));
                check_report_invariants(&spec, &report)
                    .unwrap_or_else(|e| panic!("seed {seed} {kind}: oracle: {e}"));
                let a = report.attribution.as_ref().unwrap_or_else(|| {
                    panic!("seed {seed} {kind}: sim run missing attribution")
                });
                assert_conserves(a, &format!("seed {seed} {kind}"));
                assert!(
                    a.duration >= report.end_time - 1e-12,
                    "seed {seed} {kind}: attribution horizon short of the run"
                );
            }
        }
    }
    assert_eq!(case, 9 * 2 * 12, "policy × intensity × seed grid drifted");
}
