//! Smoke tests for the figure harness: the cheapest drivers run end-to-end
//! at bench scale and their headline *shapes* hold (who wins). The full set
//! runs under `cargo bench` / `adsp experiment all`.

use adsp::experiments::{self, Scale};
use adsp::runtime::artifacts_root;

fn have_artifacts() -> bool {
    artifacts_root().join("mlp_quick/manifest.json").is_file()
}

#[test]
fn fig1_shape_adsp_waits_least_and_wins() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let table = experiments::run_by_name("fig1", Scale::Bench).unwrap();
    assert_eq!(table.rows.len(), 4);
    let idx_sync = 0;
    let conv = table.column_f64("convergence_time_s");
    let waitfrac = table.column_f64("wait_fraction");
    let names: Vec<&str> = table.rows.iter().map(|r| r[idx_sync].as_str()).collect();
    let pos = |n: &str| names.iter().position(|&x| x == n).unwrap();

    // ADSP's waiting fraction is the smallest and near zero.
    let adsp_wait = waitfrac[pos("adsp")];
    for (i, &w) in waitfrac.iter().enumerate() {
        assert!(adsp_wait <= w + 1e-9, "adsp should wait least (row {i})");
    }
    assert!(adsp_wait < 0.15, "adsp wait fraction should be negligible: {adsp_wait}");
    // BSP waits the most of all models and dominates its runtime.
    assert!(waitfrac[pos("bsp")] > 0.4, "bsp should be wait-dominated");
    // ADSP converges at least as fast as BSP and SSP.
    assert!(conv[pos("adsp")] <= conv[pos("bsp")] + 1e-9);
    assert!(conv[pos("adsp")] <= conv[pos("ssp")] + 1e-9);
}

#[test]
fn fig14_shape_adsp_adapts_best_to_slowdown() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let table = experiments::run_by_name("fig14", Scale::Bench).unwrap();
    assert_eq!(table.rows.len(), 9, "3 scenarios x 3 sync models");
    let sync_idx = table.header.iter().position(|h| h == "sync").unwrap();
    let deg_idx = table.header.iter().position(|h| h == "degradation").unwrap();
    let deg = |scenario: &str, sync: &str| -> f64 {
        table
            .filter_rows("scenario", scenario)
            .iter()
            .find(|r| r[sync_idx] == sync)
            .unwrap()[deg_idx]
            .parse()
            .unwrap()
    };
    // Acceptance: under the mid-run slowdown of the fastest worker, ADSP's
    // convergence-time degradation is strictly smaller than the barrier
    // baselines'.
    assert!(deg("slowdown", "adsp") < deg("slowdown", "ssp"));
    assert!(deg("slowdown", "adsp") < deg("slowdown", "adacomm"));
}

#[test]
fn fig16_shape_adsp_tolerates_faults_best_and_checkpoints_cost() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let table = experiments::run_by_name("fig16", Scale::Bench).unwrap();
    assert_eq!(table.rows.len(), 12, "2 crash counts x 2 intervals x 3 sync models");
    let col = |name: &str| table.header.iter().position(|h| h == name).unwrap();
    let (deg_i, over_i) = (col("degradation"), col("ckpt_overhead_s"));
    let mean_deg = |sync: &str| -> f64 {
        let rows = table.filter_rows("sync", sync);
        rows.iter().map(|r| r[deg_i].parse::<f64>().unwrap()).sum::<f64>() / rows.len() as f64
    };
    // Acceptance: ADSP's mean convergence-time degradation over the crash
    // rate x checkpoint interval sweep is the smallest of the three.
    assert!(mean_deg("adsp") < mean_deg("ssp"));
    assert!(mean_deg("adsp") < mean_deg("adacomm"));
    // The checkpoint cost model is visibly nonzero in every cell.
    for row in &table.rows {
        assert!(row[over_i].parse::<f64>().unwrap() > 0.0, "free checkpoint in {row:?}");
    }
}

#[test]
fn fig3_shape_momentum_decreases_with_rate() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let table = experiments::run_by_name("fig3", Scale::Bench).unwrap();
    // Series (a): μ_implicit strictly decreases as ΔC grows.
    let a_rows = table.filter_rows("series", "a_commit_rate");
    assert!(a_rows.len() >= 3);
    let mu_idx = table.header.iter().position(|h| h == "mu_implicit").unwrap();
    let mus: Vec<f64> = a_rows.iter().map(|r| r[mu_idx].parse().unwrap()).collect();
    for w in mus.windows(2) {
        assert!(w[1] < w[0], "mu_implicit must decrease with commit rate: {mus:?}");
    }
    // Series (c) exists with matching sweep values.
    assert!(!table.filter_rows("series", "c_explicit_momentum").is_empty());
}
