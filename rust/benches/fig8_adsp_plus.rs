//! Bench harness for paper fig8: regenerates the series at bench scale
//! (see `adsp::experiments::fig8` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig8 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig8", Scale::Bench).expect("fig8 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig8 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    assert!(table.filter_rows("variant", "adsp").len() == 1);
    assert!(table.filter_rows("variant", "adsp_plus_best").len() == 1);


    let h = BenchHarness::new("fig8").with_iters(2, 20);
    h.run("no_waiting_tau_derivation", || {
        let cluster = adsp::config::profiles::ratio_cluster(&[1.0, 1.0, 2.0, 3.0], 1.0, 0.3);
        let spec = adsp::config::SyncSpec::new(adsp::sync::SyncModelKind::AdspPlus);
        adsp::sync::AdspPlusPolicy::no_waiting_tau(&spec, &cluster).len()
    });
}
