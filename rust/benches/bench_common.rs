//! Shared bench plumbing (criterion is unavailable offline; see
//! `adsp::util::bench`). Each figure bench regenerates its paper series at
//! bench scale, asserts the headline shape, and times a representative unit.

use adsp::runtime::artifacts_root;

pub fn artifacts_ready() -> bool {
    if artifacts_root().join("mlp_quick/manifest.json").is_file() {
        true
    } else {
        eprintln!("SKIP bench: artifacts not built (run `make artifacts`)");
        false
    }
}
