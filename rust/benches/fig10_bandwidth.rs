//! Bench harness for paper fig10: regenerates the series at bench scale
//! (see `adsp::experiments::fig10` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig10 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig10", Scale::Bench).expect("fig10 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig10 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let bw = table.filter_rows("series", "a_bandwidth");
    let bw_idx = table.header.iter().position(|h| h == "bandwidth_mb_per_s").unwrap();
    let get = |n: &str| -> f64 {
        bw.iter().find(|r| r[1] == n).unwrap()[bw_idx].parse().unwrap()
    };
    // Paper shape: per-step committers use the most bandwidth.
    assert!(get("bsp") >= get("fixed_adacomm"), "BSP should out-consume Fixed ADACOMM");

    // Series (c): starving the per-worker links (LinkModel path) must not
    // speed convergence up — transfer time now grows with payload bytes.
    let conv_idx = table.header.iter().position(|h| h == "convergence_time_s").unwrap();
    let conv = |series: &str| -> f64 {
        table.filter_rows("series", series).first().unwrap()[conv_idx].parse().unwrap()
    };
    assert!(
        conv("c_link_500kBps") >= conv("c_link_unbounded") - 1e-9,
        "starved links should not converge faster"
    );


    // Ablation unit: PS apply native vs XLA artifact.
    let rt = adsp::runtime::ModelRuntime::load_by_name("mlp_quick").unwrap();
    rt.warmup().unwrap();
    let init = rt.init_params().unwrap();
    let mut u = init.zeros_like();
    for leaf in &mut u.leaves {
        for (i, v) in leaf.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
    }
    let h = BenchHarness::new("fig10").with_iters(3, 30);
    let mut w1 = init.clone();
    h.run("ps_apply_native", || adsp::runtime::native::apply_commit(&mut w1, &u, 0.1));
    let mut w2 = init.clone();
    h.run("ps_apply_xla_artifact", || rt.apply_commit(&mut w2, &u, 0.1).unwrap());
}
