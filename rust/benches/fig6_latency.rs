//! Bench harness for paper fig6: regenerates the series at bench scale
//! (see `adsp::experiments::fig6` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig6 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig6", Scale::Bench).expect("fig6 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig6 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    assert!(table.rows.len() >= 10, "delay sweep incomplete");


    let h = BenchHarness::new("fig6").with_iters(2, 20);
    h.run("cluster_delay_injection", || {
        adsp::config::profiles::ratio_cluster(&[1.0, 1.0, 2.0, 3.0], 2.0, 0.2)
            .with_extra_delay(2.0)
            .comms()
            .iter()
            .sum::<f64>()
    });
}
