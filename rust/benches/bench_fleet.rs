//! Fleet-scale scheduler bench behind the CI bench-regression gate.
//!
//! Runs the fig17 cohort experiment (`fleet_proxy` model — artifact-free,
//! loss a pure function of the step counter) at increasing populations and
//! reports scheduler throughput in events/sec. The event count per run is
//! deterministic (same spec + seed → same trace), so it is learned from
//! one probe run and then passed to the harness as `units_per_iter`.
//!
//! Guards the PR's scaling claim directly: ADSP at 10k workers must hold
//! at least half the events/sec of 1k workers (the indexed event queue is
//! O(log n); worker state is struct-of-arrays — throughput should be
//! near-flat, and a 2× collapse means a hot-path regression).
//!
//! `ADSP_BENCH_FLEET_MAX` caps the largest population (CI sets 10000 to
//! bound runtime); the 1k rung always runs.

use adsp::experiments::fig17::fleet_spec;
use adsp::run::{Backend, Run, RunReport};
use adsp::sync::SyncModelKind;
use adsp::util::BenchHarness;

fn run_fleet(n: usize) -> RunReport {
    Run::from_spec(fleet_spec(SyncModelKind::Adsp, n))
        .backend(Backend::Sim)
        .execute()
        .expect("fleet sim run failed")
}

fn main() -> anyhow::Result<()> {
    let h = BenchHarness::new("fleet").with_iters(1, 3);

    let mut pops: Vec<usize> = vec![1_000, 10_000, 100_000];
    if let Some(cap) =
        std::env::var("ADSP_BENCH_FLEET_MAX").ok().and_then(|s| s.trim().parse::<usize>().ok())
    {
        pops.retain(|&n| n <= cap.max(1_000));
    }

    let mut events_per_sec: Vec<(usize, f64)> = Vec::new();
    for &n in &pops {
        let events = run_fleet(n).events_processed();
        assert!(events > 0, "fleet run at n={n} processed no events");
        let label = format!("fleet_adsp_{}k_events", n / 1_000);
        let stats = h.run_throughput(&label, events, || run_fleet(n).total_steps);
        events_per_sec.push((n, events as f64 / stats.min_s));
    }

    // The scaling claim: 10k within 2× of 1k (skipped when the cap hides
    // either rung).
    let at = |n: usize| events_per_sec.iter().find(|&&(p, _)| p == n).map(|&(_, t)| t);
    if let (Some(t1k), Some(t10k)) = (at(1_000), at(10_000)) {
        assert!(
            t10k >= t1k / 2.0,
            "fleet throughput collapsed: 10k workers ran {t10k:.0} events/s \
             vs {t1k:.0} events/s at 1k (> 2x drop)"
        );
        println!("scaling 1k -> 10k: {t1k:.0} -> {t10k:.0} events/s");
    }

    if let Some(path) = h.write_json()? {
        println!("wrote {path:?}");
    }
    Ok(())
}
