//! Bench harness for fig16 (reproduction extension): regenerates the
//! fault-tolerance series at bench scale (crash rate × checkpoint interval
//! × sync model; see `adsp::experiments::fig16`), asserts the headline
//! shapes — ADSP's mean convergence-time degradation is the smallest, the
//! checkpoint cost is visibly nonzero, and shorter intervals trade that
//! overhead for less lost work — and times the checkpoint/restore hot path
//! on the real shard pool. Full-size: `adsp experiment fig16 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::pserver::ShardedParameterServer;
use adsp::runtime::ParamSet;
use adsp::util::BenchHarness;

fn main() {
    // Checkpoint/restore hot path first — artifact-free, so CI exercises
    // the consistent-cut machinery even when `make artifacts` never ran.
    let h = BenchHarness::new("fig16").with_iters(3, 20);
    h.run("pserver_checkpoint_restore_roundtrip", || {
        let init = ParamSet { leaves: vec![vec![0.25f32; 40_000], vec![0.5f32; 8_192]] };
        let mut ps = ShardedParameterServer::new(init.clone(), 0.2, 0.9, 4, 2);
        let u = init.zeros_like();
        for _ in 0..4 {
            ps.apply(&u);
        }
        let ckpt = ps.checkpoint();
        assert_eq!(ckpt.version, 4);
        ps.apply(&u);
        ps.restore(&ckpt);
        let (v, _) = ps.versioned_snapshot();
        assert_eq!(v, 4);
        v
    });

    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig16", Scale::Bench).expect("fig16 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig16 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    // Every crash-count × interval × sync-model cell completed.
    assert_eq!(table.rows.len(), 12, "2 crash counts x 2 intervals x 3 sync models");

    let col = |name: &str| table.header.iter().position(|h| h == name).unwrap();
    let (sync_i, ckpt_i) = (col("sync"), col("ckpt"));
    let deg_i = col("degradation");
    let wasted_i = col("wasted_steps");
    let over_i = col("ckpt_overhead_s");
    let f = |row: &Vec<String>, i: usize| -> f64 { row[i].parse().unwrap() };

    // (1) Headline: ADSP's mean degradation over the whole sweep is
    // strictly the smallest — it never blocks on crashed workers and
    // re-anchors its commit target at every failure/recovery edge.
    let mean_deg = |sync: &str| -> f64 {
        let rows = table.filter_rows("sync", sync);
        rows.iter().map(|r| f(r, deg_i)).sum::<f64>() / rows.len() as f64
    };
    let (adsp, ssp, adacomm) = (mean_deg("adsp"), mean_deg("ssp"), mean_deg("adacomm"));
    assert!(
        adsp < ssp,
        "ADSP should degrade less than SSP under faults: {adsp:.4} vs {ssp:.4}"
    );
    assert!(
        adsp < adacomm,
        "ADSP should degrade less than ADACOMM under faults: {adsp:.4} vs {adacomm:.4}"
    );

    // (2) The checkpoint cost model is visibly nonzero in every cell.
    for row in &table.rows {
        assert!(
            f(row, over_i) > 0.0,
            "checkpoint overhead must be nonzero: {} / {}",
            row[sync_i],
            row[ckpt_i]
        );
    }

    // (3) The trade-off: per sync model, the short interval pays more
    // checkpoint overhead; in aggregate it loses less work to the shard
    // failover (fewer commits past the last checkpoint roll back).
    let agg = |ckpt: &str, i: usize| -> f64 {
        table.filter_rows("ckpt", ckpt).iter().map(|r| f(r, i)).sum()
    };
    for sync in ["adsp", "ssp", "adacomm"] {
        let per = |ckpt: &str| -> f64 {
            table
                .filter_rows("sync", sync)
                .iter()
                .filter(|r| r[ckpt_i] == ckpt)
                .map(|r| f(r, over_i))
                .sum()
        };
        assert!(
            per("short") > per("long"),
            "{sync}: short intervals should cost more checkpoint overhead"
        );
    }
    assert!(
        agg("short", wasted_i) <= agg("long", wasted_i),
        "short intervals should waste no more work than long ones: {} vs {}",
        agg("short", wasted_i),
        agg("long", wasted_i)
    );
}
