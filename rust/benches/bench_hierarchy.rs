//! Fog-tier scheduler bench behind the CI bench-regression gate.
//!
//! Runs the fig18 ingress-stress experiment (`fleet_proxy`, artifact-free)
//! at a fixed 1k-member fleet twice — flat, and with one aggregator per
//! cell combining every 8 member commits — and reports scheduler
//! throughput in events/sec for each. The event count per run is
//! deterministic (same spec + seed → same trace), so it is learned from a
//! probe run and passed to the harness as `units_per_iter`.
//!
//! Guards the tier's hot-path cost: the aggregator path adds arrival /
//! flush / apply events per member commit, but it must stay within 4× of
//! the flat scheduler's events/sec — a larger gap means the fog tier's
//! bookkeeping (buffer maps, flush queues) regressed into the hot path.
//!
//! `ADSP_BENCH_HIER_WORKERS` overrides the population (CI keeps the
//! default; local profiling can push it up).

use adsp::experiments::fig18::hier_spec;
use adsp::run::{Backend, Run, RunReport};
use adsp::sync::SyncModelKind;
use adsp::util::BenchHarness;

fn run_tier(n: usize, hierarchical: bool) -> RunReport {
    Run::from_spec(hier_spec(SyncModelKind::Adsp, n, hierarchical))
        .backend(Backend::Sim)
        .execute()
        .expect("fig18 sim run failed")
}

fn main() -> anyhow::Result<()> {
    let h = BenchHarness::new("hierarchy").with_iters(1, 3);

    let n: usize = std::env::var("ADSP_BENCH_HIER_WORKERS")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(1_000);

    let mut events_per_sec: Vec<f64> = Vec::new();
    for (hierarchical, label) in [(false, "hier_flat_1k_events"), (true, "hier_fog_1k_events")] {
        let probe = run_tier(n, hierarchical);
        let events = probe.events_processed();
        assert!(events > 0, "{label}: run processed no events");
        assert!(probe.total_commits > 0, "{label}: run never committed");
        let stats = h.run_throughput(label, events, || run_tier(n, hierarchical).total_steps);
        events_per_sec.push(events as f64 / stats.min_s);
    }

    let (flat, fog) = (events_per_sec[0], events_per_sec[1]);
    assert!(
        fog >= flat / 4.0,
        "fog tier scheduler overhead exploded: {fog:.0} events/s vs {flat:.0} flat (> 4x drop)"
    );
    println!("flat -> fog at n={n}: {flat:.0} -> {fog:.0} events/s");

    if let Some(path) = h.write_json()? {
        println!("wrote {path:?}");
    }
    Ok(())
}
