//! Bench harness for paper fig4: regenerates the series at bench scale
//! (see `adsp::experiments::fig4` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig4 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig4", Scale::Bench).expect("fig4 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig4 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let conv = table.column_f64("convergence_time_s");
    let names: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    let t = |n: &str| conv[names.iter().position(|&x| x == n).unwrap()];
    assert!(t("adsp") <= t("bsp"), "paper shape: ADSP beats BSP");
    assert!(t("adsp") <= t("ssp"), "paper shape: ADSP beats SSP");


    // Unit: one k=16 local_steps execute on the CNN substitute path (mlp at bench scale).
    let rt = adsp::runtime::ModelRuntime::load_by_name("mlp_quick").unwrap();
    rt.warmup().unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let mut src = adsp::data::make_source(&rt.manifest, 0, 0);
    let h = BenchHarness::new("fig4").with_iters(3, 20);
    h.run("local_steps_k16_b32", || {
        let (xs, ys) = src.sample_batch(16, 32);
        rt.local_steps(&mut params, &mut u, &xs, &ys, 0.01).unwrap().len()
    });
}
