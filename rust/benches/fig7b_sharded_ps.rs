//! Fig. 7b (beyond-paper): sharded-PS apply throughput vs. shard count.
//!
//! Unlike the fig-harness benches this one needs no AOT artifacts — it
//! drives the real `pserver` shard-thread pool on a synthetic multi-leaf
//! model (VGG-ish leaf profile, ~1.6M params ≈ 6.4 MB dense commits) and
//! measures pipelined commit-apply throughput for S = 1, 2, 4, 8, closing
//! with a consistent snapshot so every enqueued apply is really done.
//! First it cross-checks that every shard count produces bit-identical
//! global parameters to the serial `coordinator::ps::ParameterServer`.
//!
//! On a multi-core host throughput rises with S until cores run out; the
//! sim engine's `shard_split_factor` models the same curve for fig7/fig11.

use adsp::coordinator::ParameterServer;
use adsp::pserver::ShardedParameterServer;
use adsp::runtime::ParamSet;
use adsp::util::BenchHarness;

/// Deterministic pseudo-weights (no RNG needed; values just need spread).
fn wavy(lens: &[usize], phase: f32) -> ParamSet {
    let mut i = 0.0f32;
    ParamSet {
        leaves: lens
            .iter()
            .map(|&n| {
                (0..n)
                    .map(|_| {
                        i += 1.0;
                        (i * phase).sin() * 0.01
                    })
                    .collect()
            })
            .collect(),
    }
}

fn main() {
    // VGG-ish leaf profile: a few big conv/fc slabs plus many small biases.
    let lens: Vec<usize> = vec![
        589_824, 262_144, 262_144, 147_456, 147_456, 65_536, 36_864, 16_384, 4_096, 1_024, 512,
        256, 128, 64, 32, 10,
    ];
    let total: usize = lens.iter().sum();
    println!("fig7b: model with {} leaves, {total} params", lens.len());

    let init = wavy(&lens, 0.37);
    let u = wavy(&lens, 0.11);
    let (eta, mu) = (1e-3f32, 0.9f32);

    // Correctness first: S-sharded apply is bit-identical to the serial PS
    // over the same commit sequence (momentum path — the harder one).
    let mut serial = ParameterServer::new(init.clone(), eta, mu);
    for _ in 0..4 {
        serial.apply(&u);
    }
    for s in [1usize, 2, 4, 8] {
        let mut sharded = ShardedParameterServer::new(init.clone(), eta, mu, s, 4);
        for _ in 0..4 {
            sharded.apply(&u);
        }
        let diff = sharded.snapshot().max_abs_diff(serial.global());
        assert_eq!(diff, 0.0, "shards={s}: sharded apply diverged from serial PS");
    }
    println!("fig7b: S∈{{1,2,4,8}} bit-identical to serial ParameterServer ✓");

    const COMMITS: usize = 24;
    let h = BenchHarness::new("fig7b").with_iters(2, 10);
    let mut series: Vec<(usize, f64)> = Vec::new();

    // Serial baseline: the old single-threaded apply loop.
    let mut ps0 = ParameterServer::new(init.clone(), eta, mu);
    let stats = h.run_throughput("serial_ps_24_commits", COMMITS as u64, || {
        for _ in 0..COMMITS {
            ps0.apply(&u);
        }
        ps0.commits
    });
    println!(
        "fig7b: serial baseline  {:8.1} commits/s",
        COMMITS as f64 / stats.min_s
    );

    for s in [1usize, 2, 4, 8] {
        let mut ps = ShardedParameterServer::new(init.clone(), eta, mu, s, 4);
        let name = format!("sharded_apply_24_commits_s{s}");
        let stats = h.run_throughput(&name, COMMITS as u64, || {
            for _ in 0..COMMITS {
                ps.apply(&u);
            }
            // Barrier: the snapshot drains every shard's pipeline.
            ps.snapshot().num_leaves()
        });
        series.push((s, COMMITS as f64 / stats.min_s));
    }

    println!();
    println!("shards,apply_commits_per_s");
    for (s, thr) in &series {
        println!("{s},{thr:.1}");
        assert!(*thr > 0.0 && thr.is_finite());
    }
    // No hard monotonic-speedup assert: CI hosts may be single-core. On
    // multi-core hardware the throughput column rises with S (tentpole
    // acceptance criterion) — eyeball or plot the CSV line above.

    // Machine-readable trajectory (no-op unless ADSP_BENCH_JSON_DIR set).
    if let Ok(Some(path)) = h.write_json() {
        println!("wrote {path:?}");
    }
}
