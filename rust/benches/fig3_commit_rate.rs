//! Bench harness for paper fig3: regenerates the series at bench scale
//! (see `adsp::experiments::fig3` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig3 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig3", Scale::Bench).expect("fig3 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig3 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let a = table.filter_rows("series", "a_commit_rate");
    assert!(a.len() >= 3, "commit-rate sweep missing");


    let h = BenchHarness::new("fig3").with_iters(2, 50);
    h.run("implicit_momentum_eqn3", || {
        adsp::sync::implicit_momentum(60.0, &[2.0, 3.0, 5.0], &[1.0, 1.0, 0.33])
    });
    let samples: Vec<(f64, f64)> = (0..40)
        .map(|i| (i as f64 * 3.0 + 1.0, 1.0 / (0.09 * (i as f64 * 3.0 + 1.0) + 0.5) + 0.2))
        .collect();
    h.run("reward_curve_fit", || adsp::util::fit_inverse_curve(&samples).unwrap().a3);
}
