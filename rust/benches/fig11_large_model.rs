//! Bench harness for paper fig11: regenerates the series at bench scale
//! (see `adsp::experiments::fig11` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig11 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig11", Scale::Bench).expect("fig11 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig11 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let names: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    assert!(names.contains(&"adsp") && names.contains(&"bsp"));


    let rt = adsp::runtime::ModelRuntime::load_by_name("vgg_sim").unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let mut src = adsp::data::make_source(&rt.manifest, 0, 0);
    let h = BenchHarness::new("fig11").with_iters(0, 2);
    h.run("vgg_sim_local_step_b32", || {
        let (xs, ys) = src.sample_batch(1, 32);
        rt.local_steps(&mut params, &mut u, &xs, &ys, 0.01).unwrap().len()
    });
}
