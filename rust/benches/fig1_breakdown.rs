//! Bench harness for paper fig1: regenerates the series at bench scale
//! (see `adsp::experiments::fig1` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig1 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig1", Scale::Bench).expect("fig1 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig1 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let wf = table.column_f64("wait_fraction");
    let names: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    let adsp = names.iter().position(|&n| n == "adsp").unwrap();
    assert!(wf[adsp] < 0.15, "paper shape: ADSP waiting ~0 (got {})", wf[adsp]);


    // Unit: one full bench-scale ADSP run on the motivating cluster.
    let h = BenchHarness::new("fig1").with_iters(0, 3);
    h.run("adsp_3worker_run", || {
        let cluster = adsp::config::profiles::ratio_cluster(&[1.0, 1.0, 3.0], 2.0, 0.3);
        let mut spec =
            adsp::experiments::common::bench_spec(adsp::sync::SyncModelKind::Adsp, cluster);
        spec.max_virtual_secs = 120.0;
        spec.max_total_steps = 2000;
        adsp::simulation::SimEngine::new(spec).unwrap().run().unwrap().total_steps
    });
}
