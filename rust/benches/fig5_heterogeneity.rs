//! Bench harness for paper fig5: regenerates the series at bench scale
//! (see `adsp::experiments::fig5` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig5 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig5", Scale::Bench).expect("fig5 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig5 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    // Paper shape: ADSP at least matches Fixed ADACOMM at every H and the
    // advantage at the largest H is positive.
    let rows = table.filter_rows("sync", "adsp");
    assert!(!rows.is_empty());
    let su_idx = table.header.iter().position(|h| h == "speedup_vs_fixed").unwrap();
    let last_speedup: f64 = rows.last().unwrap()[su_idx].parse().unwrap();
    assert!(last_speedup >= -0.05, "ADSP should not lose badly at high H: {last_speedup}");


    let h = BenchHarness::new("fig5").with_iters(2, 20);
    h.run("heterogeneity_rescale", || {
        let base = adsp::config::profiles::ec2_cluster(18, 1.0, 0.3);
        adsp::config::profiles::scale_speeds_to_heterogeneity(&base, 3.2).heterogeneity()
    });
}
