//! Bench harness for fig15 (reproduction extension): regenerates the
//! communication-stress series at bench scale (see
//! `adsp::experiments::fig15` docs for the blackout severities), asserts
//! the headline shape — ADSP's convergence-time degradation under PS-link
//! blackouts is the smallest of the swept models — and times the network
//! hot paths. Full-size: `adsp experiment fig15 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::cluster::{scenarios, ClusterState};
use adsp::config::profiles::ec2_cluster;
use adsp::experiments::fig15::SEVERITIES;
use adsp::experiments::{self, Scale};
use adsp::network::{IngressDiscipline, IngressQueue, LinkModel};
use adsp::sync::SyncModelKind;
use adsp::util::{BenchHarness, Rng};

fn main() {
    // Network hot paths first — artifact-free, so CI exercises the link /
    // contention / blackout machinery even when `make artifacts` never ran.
    let h = BenchHarness::new("fig15").with_iters(3, 50);
    h.run("link_transfer_1k_commits", || {
        let link = LinkModel { bandwidth_bytes_per_sec: 1e6, latency_secs: 0.02, jitter: 0.1 };
        let mut rng = Rng::new(42);
        let mut acc = 0.0;
        for i in 0..1000u64 {
            acc += link.transfer_secs_jittered(1000 + i * 37, &mut rng);
        }
        acc
    });
    h.run("ingress_fairshare_1k_commits", || {
        let mut q = IngressQueue::new(8e6, IngressDiscipline::FairShare);
        let mut t = 0.0;
        let mut last = 0.0;
        for i in 0..1000u64 {
            t += 0.01;
            last = q.admit(t, 50_000 + i * 13);
        }
        last
    });
    h.run("blackout_preset_build_apply", || {
        let cluster = ec2_cluster(18, 1.0, 0.3);
        let tl = scenarios::preset("blackout", &cluster, 600.0).expect("preset");
        tl.validate(cluster.m()).expect("validate");
        let mut state = ClusterState::new(&cluster, SyncModelKind::Adsp, 128, &[32, 64, 128]);
        for ev in tl.events() {
            state.apply_event(ev).expect("apply");
        }
        state.blackout_until.iter().filter(|&&t| t > 0.0).count()
    });

    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig15", Scale::Bench).expect("fig15 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig15 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    // Every severity × sync-model combination completed.
    assert_eq!(table.rows.len(), 9, "3 blackout severities x 3 sync models");

    let deg_idx = table.header.iter().position(|h| h == "degradation").unwrap();
    let sync_idx = table.header.iter().position(|h| h == "sync").unwrap();
    let mean_degradation = |sync: &str| -> f64 {
        let rows: Vec<f64> = table
            .rows
            .iter()
            .filter(|r| r[sync_idx] == sync)
            .map(|r| r[deg_idx].parse().unwrap())
            .collect();
        assert_eq!(rows.len(), SEVERITIES.len(), "missing rows for {sync}");
        rows.iter().sum::<f64>() / rows.len() as f64
    };

    // Acceptance shape: across the blackout severities, ADSP's mean
    // convergence-time degradation is the smallest — its unaffected
    // workers keep committing, its affected workers keep training to
    // their own deadlines, and it re-anchors when the blackout lifts;
    // the barrier models stall on the silent workers.
    let adsp = mean_degradation("adsp");
    let ssp = mean_degradation("ssp");
    let adacomm = mean_degradation("adacomm");
    assert!(
        adsp < ssp,
        "ADSP should degrade less than SSP under blackouts: {adsp:.4} vs {ssp:.4}"
    );
    assert!(
        adsp < adacomm,
        "ADSP should degrade less than ADACOMM under blackouts: {adsp:.4} vs {adacomm:.4}"
    );
}
