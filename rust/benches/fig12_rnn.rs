//! Bench harness for paper fig12: regenerates the series at bench scale
//! (see `adsp::experiments::fig12` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig12 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig12", Scale::Bench).expect("fig12 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig12 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let conv = table.column_f64("convergence_time_s");
    let names: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    let t = |n: &str| conv[names.iter().position(|&x| x == n).unwrap()];
    assert!(t("adsp") <= t("bsp") * 1.05, "paper shape: ADSP ~fastest on the RNN");


    let rt = adsp::runtime::ModelRuntime::load_by_name("rnn_rail").unwrap();
    let mut params = rt.init_params().unwrap();
    let mut u = params.zeros_like();
    let mut src = adsp::data::make_source(&rt.manifest, 0, 0);
    let h = BenchHarness::new("fig12").with_iters(2, 10);
    h.run("rnn_local_steps_k4_b128", || {
        let (xs, ys) = src.sample_batch(4, 128);
        rt.local_steps(&mut params, &mut u, &xs, &ys, 0.01).unwrap().len()
    });
}
