//! Hot-path micro-benches behind the CI bench-regression gate.
//!
//! Artifact-free on purpose (no AOT models, no PJRT): every workload here
//! is pure in-process Rust, so the bench runs on any checkout — including
//! CI — and its `BENCH_hotpath.json` dump (set `ADSP_BENCH_JSON_DIR`) is
//! diffed against the committed baseline in `benches/baselines/` by
//! `tools/check_bench_regression.py`. Covered paths:
//!
//! * sharded-PS pipelined commit apply (the realtime engine's PS side),
//! * the native dense commit-apply kernel (the simulator's PS arithmetic),
//! * top-k sparsification (the compressed-commit wire path),
//! * the observability registry and trace recorder (the tap hot loop —
//!   regression here silently taxes every observed run).

use adsp::obs::{
    MetricsRegistry, ObsConfig, ObsHub, Span, SpanId, SpanPhase, SpanState, SpanTrack,
    TraceRecorder,
};
use adsp::pserver::ShardedParameterServer;
use adsp::runtime::{native, ParamSet};
use adsp::util::{BenchHarness, Json};

/// Deterministic pseudo-weights (no RNG needed; values just need spread).
fn wavy(lens: &[usize], phase: f32) -> ParamSet {
    let mut i = 0.0f32;
    ParamSet {
        leaves: lens
            .iter()
            .map(|&n| {
                (0..n)
                    .map(|_| {
                        i += 1.0;
                        (i * phase).sin() * 0.01
                    })
                    .collect()
            })
            .collect(),
    }
}

fn main() -> anyhow::Result<()> {
    let h = BenchHarness::new("hotpath").with_iters(2, 10);

    // ---- sharded PS: pipelined commit apply + snapshot barrier ----
    let ps_lens: Vec<usize> = vec![262_144, 131_072, 16_384, 1_024, 64];
    let ps_init = wavy(&ps_lens, 0.37);
    let ps_u = wavy(&ps_lens, 0.11);
    const COMMITS: u64 = 16;
    let mut ps = ShardedParameterServer::new(ps_init, 1e-3, 0.9, 2, 4);
    h.run_throughput("sharded_ps_apply_s2", COMMITS, || {
        for _ in 0..COMMITS {
            ps.apply(&ps_u);
        }
        // Barrier: the snapshot drains every shard's pipeline.
        ps.snapshot().num_leaves()
    });

    // ---- native dense apply: the simulator's PS arithmetic ----
    let dense_lens: Vec<usize> = vec![786_432, 262_144, 4_096, 512];
    let total: u64 = dense_lens.iter().map(|&n| n as u64).sum();
    let mut w = wavy(&dense_lens, 0.29);
    let u = wavy(&dense_lens, 0.13);
    h.run_throughput("native_apply_commit_1m", total, || {
        native::apply_commit(&mut w, &u, 1e-3);
        w.num_leaves()
    });

    // ---- top-k sparsification: the compressed-commit wire path ----
    let topk_lens: Vec<usize> = vec![262_144];
    let topk_src = wavy(&topk_lens, 0.19);
    h.run_throughput("topk_sparsify_256k_1pct", 262_144, || {
        let mut v = topk_src.clone();
        native::topk_sparsify(&mut v, 0.01)
    });

    // ---- observability registry: the tap hot loop ----
    const OPS: u64 = 10_000;
    h.run_throughput("metrics_registry_10k_ops", OPS, || {
        let mut reg = MetricsRegistry::new();
        for i in 0..OPS {
            reg.inc("sim/events/commit_arrive");
            reg.set_gauge("sim/event_queue_depth", i as f64);
            reg.observe("sim/ps_apply_turnaround_secs", (i % 97) as f64 * 1e-4);
        }
        reg.counter("sim/events/commit_arrive")
    });

    // ---- trace recorder: bounded ring at capacity ----
    const EVENTS: u64 = 10_000;
    h.run_throughput("trace_record_10k_events", EVENTS, || {
        let mut tr = TraceRecorder::new(4096);
        for i in 0..EVENTS {
            let t = i as f64 * 0.5;
            let data = vec![("worker", Json::Num((i % 8) as f64))];
            tr.record(t, t * 0.02, "commit", data);
        }
        tr.len()
    });

    // ---- lineage spans: the span-emit tap at ring capacity ----
    // Every span is one id allocation + field serialization + a ring
    // insert through the hub; a regression here taxes every `--spans`
    // run, so the floor pins span-on emit throughput.
    const SPANS: u64 = 10_000;
    let hub = ObsHub::new(ObsConfig { metrics: false, trace_capacity: Some(4096), spans: true });
    h.run_throughput("span_record_10k", SPANS, || {
        for i in 0..SPANS {
            let t = i as f64 * 1e-3;
            hub.record_span(&Span {
                id: hub.next_span_id(),
                parent: if i % 4 == 0 { None } else { Some(SpanId(i)) },
                track: SpanTrack::Worker((i % 8) as usize),
                commit: i / 8,
                phase: SpanPhase::Compute,
                state: SpanState::Completed,
                t0: t,
                t1: t + 5e-4,
            });
        }
        hub.trace_len()
    });

    if let Some(path) = h.write_json()? {
        println!("wrote {path:?}");
    }
    Ok(())
}
