//! Bench harness for paper fig7: regenerates the series at bench scale
//! (see `adsp::experiments::fig7` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig7 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig7", Scale::Bench).expect("fig7 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig7 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let conv = table.column_f64("convergence_time_s");
    assert!(conv.iter().all(|&t| t > 0.0));


    let h = BenchHarness::new("fig7").with_iters(2, 20);
    h.run("ec2_profile_36", || adsp::config::profiles::ec2_cluster(36, 1.0, 0.3).m());
}
