//! Bench harness for paper fig9: regenerates the series at bench scale
//! (see `adsp::experiments::fig9` docs for the workload and the paper shape
//! being reproduced), asserts the headline shape, and times the figure's
//! representative hot-path unit. Full-size: `adsp experiment fig9 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::experiments::{self, Scale};
use adsp::util::BenchHarness;

fn main() {
    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig9", Scale::Bench).expect("fig9 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig9 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    let conv = table.column_f64("convergence_time_s");
    let names: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    let t = |n: &str| conv[names.iter().position(|&x| x == n).unwrap()];
    assert!(t("adsp") <= t("bsp"), "paper shape: ADSP still fastest");


    let h = BenchHarness::new("fig9").with_iters(2, 50);
    h.run("assign_batchtune_sizes", || {
        adsp::sync::assign_batchtune_sizes(&[1.0, 1.0, 2.0, 3.0], 128, &[32, 64, 128, 256])
    });
}
