//! Bench harness for fig14 (reproduction extension): regenerates the
//! dynamic-cluster adaptability series at bench scale (see
//! `adsp::experiments::fig14` docs for the scenarios), asserts the
//! headline shape — ADSP degrades less than the barrier baselines when
//! the cluster shifts under it — and times the timeline hot path.
//! Full-size: `adsp experiment fig14 --full`.

#[path = "bench_common.rs"]
mod bench_common;

use adsp::cluster::{scenarios, ClusterState};
use adsp::config::profiles::ec2_cluster;
use adsp::experiments::{self, Scale};
use adsp::sync::SyncModelKind;
use adsp::util::BenchHarness;

fn main() {
    // Timeline hot path first — artifact-free, so CI exercises the
    // scenario/event machinery even when `make artifacts` never ran.
    let h = BenchHarness::new("fig14").with_iters(3, 50);
    h.run("timeline_build_validate_apply", || {
        let cluster = ec2_cluster(18, 1.0, 0.3);
        let tl = scenarios::preset("churn", &cluster, 600.0).expect("preset");
        tl.validate(cluster.m()).expect("validate");
        let mut state = ClusterState::new(&cluster, SyncModelKind::Adsp, 128, &[32, 64, 128]);
        for ev in tl.events() {
            state.apply_event(ev).expect("apply");
        }
        state.active.iter().filter(|&&a| a).count()
    });

    if !bench_common::artifacts_ready() {
        return;
    }
    let t0 = std::time::Instant::now();
    let table = experiments::run_by_name("fig14", Scale::Bench).expect("fig14 failed");
    table.print();
    table.write_csv().expect("csv");
    println!("[fig14 series regenerated in {:.1}s]", t0.elapsed().as_secs_f64());

    // Every scenario × sync-model combination completed.
    assert_eq!(table.rows.len(), 9, "3 scenarios x 3 sync models");

    let deg_idx = table.header.iter().position(|h| h == "degradation").unwrap();
    let sync_idx = table.header.iter().position(|h| h == "sync").unwrap();
    let degradation = |scenario: &str, sync: &str| -> f64 {
        table
            .filter_rows("scenario", scenario)
            .iter()
            .find(|r| r[sync_idx] == sync)
            .unwrap_or_else(|| panic!("no row for {scenario}/{sync}"))[deg_idx]
            .parse()
            .unwrap()
    };

    // Acceptance shape: under the mid-run 4x slowdown of the fastest
    // worker, ADSP's convergence-time degradation is strictly smaller
    // than SSP's and ADACOMM's — the barrier models inherit the new
    // straggler's pace, ADSP re-targets its commit rates and keeps going.
    let adsp = degradation("slowdown", "adsp");
    let ssp = degradation("slowdown", "ssp");
    let adacomm = degradation("slowdown", "adacomm");
    assert!(
        adsp < ssp,
        "ADSP should degrade less than SSP under slowdown: {adsp:.4} vs {ssp:.4}"
    );
    assert!(
        adsp < adacomm,
        "ADSP should degrade less than ADACOMM under slowdown: {adsp:.4} vs {adacomm:.4}"
    );
}
